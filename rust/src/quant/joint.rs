//! The dataflow-based **joint calibrator** (§1.2.1–1.2.2): walks the
//! unified-module graph in topological order, running Algorithm 1 per
//! module with the *quantized* prefix as input — so each module's search
//! sees the accumulated quantization error of everything upstream, and
//! residual shortcuts are aligned against the scales actually chosen for
//! their producers.
//!
//! Calibration uses a single image by default (paper §2.1: "our
//! optimization is conducted on a single image"); `CalibConfig::images`
//! widens the batch for the ablation study.

use std::collections::HashMap;

use crate::error::DfqError;
use crate::graph::bn_fold::FoldedParams;
use crate::graph::{Graph, ModuleKind};
use crate::quant::algo1::{self, ModuleProblem, SearchConfig};
use crate::quant::params::QuantSpec;
use crate::quant::scheme;
use crate::quant::stats::{CalibStats, ModuleStat};
use crate::tensor::{Tensor, TensorI32};
use crate::util::mathutil::mse;
use crate::util::timer::Timer;

/// Joint-calibration configuration.
#[derive(Clone, Copy, Debug)]
pub struct CalibConfig {
    /// bit-width (paper: 8; Table 4 sweeps 6–8)
    pub n_bits: u32,
    /// search window width τ (paper: 4)
    pub tau: i32,
    /// number of calibration images (paper: 1)
    pub images: usize,
    /// ablation: place quantization points per-layer instead of
    /// per-unified-module (the dataflow hypothesis test, DESIGN.md §7)
    pub unfused: bool,
}

impl Default for CalibConfig {
    fn default() -> Self {
        CalibConfig { n_bits: 8, tau: 4, images: 1, unfused: false }
    }
}

/// The joint calibrator.
pub struct JointCalibrator {
    cfg: CalibConfig,
}

/// Calibration output: the spec plus per-module statistics (Fig. 2).
pub struct CalibOutcome {
    /// the calibrated quantization parameters
    pub spec: QuantSpec,
    /// per-module reconstruction statistics
    pub stats: CalibStats,
    /// wall-clock seconds spent (Table 2)
    pub seconds: f64,
}

impl JointCalibrator {
    /// Create with a config.
    pub fn new(cfg: CalibConfig) -> Self {
        JointCalibrator { cfg }
    }

    /// Calibrate a model on `calib` (NHWC, normalised, batch =
    /// `cfg.images`), given its graph, folded params and the FP oracle
    /// activations produced by [`crate::engine::fp::FpEngine::run_acts`]
    /// (or fetched through the PJRT `fp_acts` artifact — both are
    /// accepted since they agree to f32 precision). Malformed inputs
    /// (dangling names, missing params/targets) are typed errors.
    pub fn calibrate_with_targets(
        &self,
        graph: &Graph,
        folded: &HashMap<String, FoldedParams>,
        calib: &Tensor,
        fp_acts: &HashMap<String, Tensor>,
    ) -> Result<CalibOutcome, DfqError> {
        let timer = Timer::start();
        let cfg = self.cfg;
        let scfg = SearchConfig { n_bits: cfg.n_bits, tau: cfg.tau };
        let mut spec = QuantSpec::new(cfg.n_bits);
        spec.input_frac = algo1::search_input_frac(calib, cfg.n_bits, cfg.tau);
        let mut stats = CalibStats::default();

        // integer activations of the calibrated prefix
        let mut iacts: HashMap<String, TensorI32> = HashMap::new();
        iacts.insert(
            "input".to_string(),
            scheme::quantize_tensor(calib, spec.input_frac, cfg.n_bits, false),
        );

        for m in &graph.modules {
            let target = fp_acts.get(&m.name).ok_or_else(|| {
                DfqError::data(format!(
                    "module '{}' has no FP target activation",
                    m.name
                ))
            })?;
            match &m.kind {
                ModuleKind::Gap => {
                    // no parameters; execute and record
                    let eng = crate::engine::int::IntEngine::new(graph, folded, &spec);
                    let out = eng.run_module(m, &iacts)?;
                    let n = spec.try_value_frac(graph, &m.src)?;
                    let deq = scheme::dequantize_tensor(&out, n);
                    stats.push(ModuleStat {
                        name: m.name.clone(),
                        fig1_case: m.fig1_case(),
                        mse: mse(&deq.data, &target.data),
                        n_w: 0,
                        n_b: 0,
                        n_o: n,
                        out_shift: 0,
                        error: 0.0,
                    });
                    iacts.insert(m.name.clone(), out);
                }
                ModuleKind::Conv { .. } | ModuleKind::Dense { .. } => {
                    let p = folded.get(&m.name).ok_or_else(|| {
                        DfqError::data(format!(
                            "module '{}' has no folded parameters",
                            m.name
                        ))
                    })?;
                    let n_x = spec.try_value_frac(graph, &m.src)?;
                    let res = match m.res.as_ref() {
                        Some(r) => {
                            let rt = iacts.get(r).ok_or_else(|| {
                                DfqError::graph(format!(
                                    "{}: missing residual activation '{r}'",
                                    m.name
                                ))
                            })?;
                            Some((rt, spec.try_value_frac(graph, r)?))
                        }
                        None => None,
                    };
                    let problem = ModuleProblem {
                        module: m,
                        x_int: iacts.get(&m.src).ok_or_else(|| {
                            DfqError::graph(format!(
                                "{}: missing input activation '{}'",
                                m.name, m.src
                            ))
                        })?,
                        n_x,
                        w: &p.w,
                        b: &p.b,
                        res,
                        target,
                    };
                    let r = if cfg.unfused {
                        self.search_unfused(&problem, scfg)
                    } else {
                        algo1::search(&problem, scfg)
                    };
                    spec.modules.insert(m.name.clone(), r.shifts);
                    // execute the module with the winning shifts so the
                    // next module calibrates against real quantized input
                    let eng = crate::engine::int::IntEngine::new(graph, folded, &spec);
                    let out = eng.run_module(m, &iacts)?;
                    let deq = scheme::dequantize_tensor(&out, r.shifts.n_o);
                    stats.push(ModuleStat {
                        name: m.name.clone(),
                        fig1_case: m.fig1_case(),
                        mse: mse(&deq.data, &target.data),
                        n_w: r.shifts.n_w,
                        n_b: r.shifts.n_b,
                        n_o: r.shifts.n_o,
                        out_shift: r.shifts.out_shift(n_x),
                        error: r.error,
                    });
                    iacts.insert(m.name.clone(), out);
                }
            }
        }
        Ok(CalibOutcome { spec, stats, seconds: timer.secs() })
    }

    /// Convenience: compute the FP targets with the rust oracle engine
    /// and calibrate.
    pub fn calibrate(
        &self,
        graph: &Graph,
        folded: &HashMap<String, FoldedParams>,
        calib: &Tensor,
    ) -> Result<CalibOutcome, DfqError> {
        let fp = crate::engine::fp::FpEngine::new(graph, folded);
        let acts = fp.run_acts(calib)?;
        self.calibrate_with_targets(graph, folded, calib, &acts)
    }

    /// The unfused ablation still uses Algorithm 1, but the target the
    /// engine will later reproduce goes through the extra per-layer
    /// quantization points, so the effective search is identical — the
    /// difference materialises at engine run time via `pre_frac`
    /// (see `ablation_pre_fracs`).
    fn search_unfused(
        &self,
        p: &ModuleProblem<'_>,
        scfg: SearchConfig,
    ) -> algo1::SearchResult {
        algo1::search(p, scfg)
    }

    /// Derive the intermediate (pre-ReLU/pre-add) fractional bits for
    /// the unfused ablation: the conv output is quantized at the scale
    /// that best covers the raw accumulator range — one extra
    /// quantization operation per layer, as in instant-after-conv
    /// schemes.
    pub fn ablation_pre_fracs(
        &self,
        graph: &Graph,
        folded: &HashMap<String, FoldedParams>,
        calib: &Tensor,
        spec: &QuantSpec,
    ) -> Result<HashMap<String, i32>, DfqError> {
        let fp = crate::engine::fp::FpEngine::new(graph, folded);
        let acts = fp.run_acts(calib)?;
        let mut out = HashMap::new();
        for m in graph.weight_modules() {
            // pre-activation range ~ range of the module output before
            // relu; approximate with the FP activation magnitude (the
            // conv output magnitude bound)
            let max = acts[&m.name].max_abs();
            let cands = algo1::frac_window(max, spec.n_bits, self.cfg.tau);
            out.insert(m.name.clone(), cands[self.cfg.tau as usize / 2]);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::UnifiedModule;

    /// A small residual CNN with all four Fig. 1 cases.
    fn toy_model() -> (Graph, HashMap<String, FoldedParams>) {
        let graph = Graph {
            name: "toy".into(),
            input_hwc: (8, 8, 3),
            modules: vec![
                UnifiedModule {
                    name: "stem".into(),
                    kind: ModuleKind::Conv { kh: 3, kw: 3, cin: 3, cout: 4, stride: 1 },
                    src: "input".into(),
                    res: None,
                    relu: true,
                },
                UnifiedModule {
                    name: "proj".into(),
                    kind: ModuleKind::Conv { kh: 1, kw: 1, cin: 4, cout: 8, stride: 2 },
                    src: "stem".into(),
                    res: None,
                    relu: false,
                },
                UnifiedModule {
                    name: "c1".into(),
                    kind: ModuleKind::Conv { kh: 3, kw: 3, cin: 4, cout: 8, stride: 2 },
                    src: "stem".into(),
                    res: None,
                    relu: true,
                },
                UnifiedModule {
                    name: "c2".into(),
                    kind: ModuleKind::Conv { kh: 3, kw: 3, cin: 8, cout: 8, stride: 1 },
                    src: "c1".into(),
                    res: Some("proj".into()),
                    relu: false,
                },
                UnifiedModule {
                    name: "gap".into(),
                    kind: ModuleKind::Gap,
                    src: "c2".into(),
                    res: None,
                    relu: false,
                },
                UnifiedModule {
                    name: "fc".into(),
                    kind: ModuleKind::Dense { cin: 8, cout: 5 },
                    src: "gap".into(),
                    res: None,
                    relu: false,
                },
            ],
        };
        let mut rng = crate::util::rng::Pcg::new(31);
        let mut folded = HashMap::new();
        for m in graph.weight_modules() {
            let (shape, fan_in): (Vec<usize>, usize) = match &m.kind {
                ModuleKind::Conv { kh, kw, cin, cout, .. } => {
                    (vec![*kh, *kw, *cin, *cout], kh * kw * cin)
                }
                ModuleKind::Dense { cin, cout } => (vec![*cin, *cout], *cin),
                ModuleKind::Gap => unreachable!(),
            };
            let std = (2.0 / fan_in as f32).sqrt();
            let n: usize = shape.iter().product();
            let cout = *shape.last().unwrap();
            folded.insert(
                m.name.clone(),
                FoldedParams {
                    w: Tensor::from_vec(&shape, (0..n).map(|_| rng.normal_ms(0.0, std)).collect()),
                    b: (0..cout).map(|_| rng.normal_ms(0.0, 0.1)).collect(),
                },
            );
        }
        (graph, folded)
    }

    #[test]
    fn calibrates_all_modules_with_low_final_error() {
        let (graph, folded) = toy_model();
        let mut rng = crate::util::rng::Pcg::new(32);
        let x = Tensor::from_vec(&[1, 8, 8, 3], (0..192).map(|_| rng.normal()).collect());
        let out = JointCalibrator::new(CalibConfig::default())
            .calibrate(&graph, &folded, &x)
            .unwrap();
        assert_eq!(out.spec.modules.len(), 5); // gap has no params
        // quantized final output close to FP final output
        let fp = crate::engine::fp::FpEngine::new(&graph, &folded);
        let want = fp.run(&x).unwrap();
        let eng = crate::engine::int::IntEngine::new(&graph, &folded, &out.spec);
        let got = eng.run_dequant(&x).unwrap();
        let rel = crate::util::mathutil::mse(&got.data, &want.data)
            / want.data.iter().map(|v| v * v).sum::<f32>().max(1e-9) as f64
            * want.data.len() as f64;
        assert!(rel < 0.02, "relative error {rel}");
        assert!(out.seconds >= 0.0);
        // stats recorded for every module including gap
        assert_eq!(out.stats.modules.len(), graph.modules.len());
    }

    #[test]
    fn multi_image_calibration_runs() {
        let (graph, folded) = toy_model();
        let mut rng = crate::util::rng::Pcg::new(33);
        let x = Tensor::from_vec(&[2, 8, 8, 3], (0..384).map(|_| rng.normal()).collect());
        let out = JointCalibrator::new(CalibConfig { images: 2, ..Default::default() })
            .calibrate(&graph, &folded, &x)
            .unwrap();
        assert_eq!(out.spec.modules.len(), 5);
    }

    #[test]
    fn lower_bits_give_higher_or_equal_error() {
        let (graph, folded) = toy_model();
        let mut rng = crate::util::rng::Pcg::new(34);
        let x = Tensor::from_vec(&[1, 8, 8, 3], (0..192).map(|_| rng.normal()).collect());
        let fp = crate::engine::fp::FpEngine::new(&graph, &folded);
        let want = fp.run(&x).unwrap();
        let mut errs = Vec::new();
        for bits in [8u32, 6, 4] {
            let out = JointCalibrator::new(CalibConfig { n_bits: bits, ..Default::default() })
                .calibrate(&graph, &folded, &x)
                .unwrap();
            let eng = crate::engine::int::IntEngine::new(&graph, &folded, &out.spec);
            let got = eng.run_dequant(&x).unwrap();
            errs.push(crate::util::mathutil::mse(&got.data, &want.data));
        }
        assert!(errs[0] <= errs[1] * 1.5 + 1e-12, "{errs:?}");
        assert!(errs[1] <= errs[2] * 1.5 + 1e-12, "{errs:?}");
        assert!(errs[0] < errs[2], "{errs:?}");
    }

    #[test]
    fn fused_beats_unfused_dataflow() {
        // the paper's central hypothesis: fewer quantization points ->
        // lower reconstruction error at the output
        let (graph, folded) = toy_model();
        let mut rng = crate::util::rng::Pcg::new(35);
        let x = Tensor::from_vec(&[1, 8, 8, 3], (0..192).map(|_| rng.normal()).collect());
        let fp = crate::engine::fp::FpEngine::new(&graph, &folded);
        let want = fp.run(&x).unwrap();

        let cal = JointCalibrator::new(CalibConfig::default());
        let out = cal.calibrate(&graph, &folded, &x).unwrap();
        let eng = crate::engine::int::IntEngine::new(&graph, &folded, &out.spec);
        let fused_mse = crate::util::mathutil::mse(&eng.run_dequant(&x).unwrap().data, &want.data);

        let pre = cal.ablation_pre_fracs(&graph, &folded, &x, &out.spec).unwrap();
        let mut eng2 = crate::engine::int::IntEngine::new(&graph, &folded, &out.spec);
        eng2.pre_frac = Some(pre);
        let unfused_mse = crate::util::mathutil::mse(&eng2.run_dequant(&x).unwrap().data, &want.data);
        assert!(
            fused_mse <= unfused_mse + 1e-12,
            "fused {fused_mse} vs unfused {unfused_mse}"
        );
    }
}
