//! Per-module calibration statistics — the data behind the paper's
//! Fig. 2 (MSE vs residual-block depth, shifting bits vs layer depth)
//! and the `dfq inspect` output.

/// One calibrated module's record.
#[derive(Clone, Debug)]
pub struct ModuleStat {
    /// module name
    pub name: String,
    /// Fig.-1 case (a–d)
    pub fig1_case: char,
    /// MSE between dequantized and FP activations
    pub mse: f64,
    /// chosen fractional bits
    pub n_w: i32,
    /// chosen bias fractional bits
    pub n_b: i32,
    /// chosen output fractional bits
    pub n_o: i32,
    /// the deployed requantization shift (N_x + N_w − N_o)
    pub out_shift: i32,
    /// Algorithm-1 reconstruction error ‖O − O^q‖₂
    pub error: f64,
}

/// Statistics for a whole calibration run.
#[derive(Clone, Debug, Default)]
pub struct CalibStats {
    /// per-module records in execution order
    pub modules: Vec<ModuleStat>,
}

impl CalibStats {
    /// Append a record.
    pub fn push(&mut self, s: ModuleStat) {
        self.modules.push(s);
    }

    /// Fig. 2a series: for residual modules (case c/d), the MSE by block
    /// index, alongside the two preceding convs of the same block.
    /// Returns (block_index, conv1_mse, conv2_or_add_mse).
    pub fn residual_mse_series(&self) -> Vec<(usize, f64, f64)> {
        let mut out = Vec::new();
        let mut block = 0usize;
        let mut last_conv_mse = 0.0;
        for m in &self.modules {
            match m.fig1_case {
                'b' => last_conv_mse = m.mse,
                'c' | 'd' => {
                    out.push((block, last_conv_mse, m.mse));
                    block += 1;
                }
                _ => {}
            }
        }
        out
    }

    /// Fig. 2b series: deployed shift value per weighted layer, in depth
    /// order.
    pub fn shift_series(&self) -> Vec<(usize, i32)> {
        self.modules
            .iter()
            .filter(|m| m.fig1_case != 'g' && !(m.n_w == 0 && m.n_b == 0))
            .enumerate()
            .map(|(i, m)| (i, m.out_shift))
            .collect()
    }

    /// Distribution of deployed shifts (min, median, max).
    pub fn shift_summary(&self) -> (i32, i32, i32) {
        let mut shifts: Vec<i32> = self.shift_series().iter().map(|(_, s)| *s).collect();
        if shifts.is_empty() {
            return (0, 0, 0);
        }
        shifts.sort_unstable();
        (shifts[0], shifts[shifts.len() / 2], shifts[shifts.len() - 1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stat(name: &str, case: char, mse: f64, out_shift: i32) -> ModuleStat {
        ModuleStat {
            name: name.into(),
            fig1_case: case,
            mse,
            n_w: 7,
            n_b: 7,
            n_o: 4,
            out_shift,
            error: 0.0,
        }
    }

    #[test]
    fn residual_series_pairs_convs_with_adds() {
        let mut s = CalibStats::default();
        s.push(stat("c1", 'b', 0.1, 8));
        s.push(stat("c2", 'c', 0.3, 9));
        s.push(stat("c3", 'b', 0.15, 7));
        s.push(stat("c4", 'd', 0.4, 6));
        let series = s.residual_mse_series();
        assert_eq!(series.len(), 2);
        assert_eq!(series[0], (0, 0.1, 0.3));
        assert_eq!(series[1], (1, 0.15, 0.4));
        // the paper's Fig. 2a observation: addition MSE > conv MSE
        assert!(series.iter().all(|(_, c, a)| a > c));
    }

    #[test]
    fn shift_summary_ranges() {
        let mut s = CalibStats::default();
        for (i, sh) in [3, 8, 5, 9, 2].iter().enumerate() {
            s.push(stat(&format!("m{i}"), 'b', 0.1, *sh));
        }
        let (lo, med, hi) = s.shift_summary();
        assert_eq!((lo, hi), (2, 9));
        assert_eq!(med, 5);
    }
}
