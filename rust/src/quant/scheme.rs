//! The quantization scheme (paper Eq. 1) and its integer-shift algebra
//! (Eq. 3–4) — bit-exact mirror of `python/compile/kernels/ref.py`.
//!
//! Conventions shared across the whole stack (python oracle, Pallas
//! kernels, this engine, the PJRT artifacts):
//!
//! * **round-half-up**: `round(x) = floor(x + 0.5)`;
//! * `quantize_int(r, N, bits) = clamp(round(r * 2^N), qmin, qmax)`;
//! * integer requantization by shift `s` uses
//!   `(v + (1 << (s-1))) >> s` (arithmetic shift ≡ floor division),
//!   exactly `floor(v / 2^s + 0.5)`; negative `s` left-shifts;
//! * ReLU modules clamp to the **unsigned** range `[0, 2^bits - 1]`
//!   (the paper's "[0, 255] if the bit-width is 8-bit"), other modules
//!   to the signed range.

use crate::tensor::{Tensor, TensorI32};

/// Quantized-range limits for a bit-width.
#[inline]
pub fn qrange(n_bits: u32, unsigned: bool) -> (i32, i32) {
    if unsigned {
        (0, (1i32 << n_bits) - 1)
    } else {
        (-(1i32 << (n_bits - 1)), (1i32 << (n_bits - 1)) - 1)
    }
}

/// Round-half-up: `floor(x + 0.5)`.
#[inline]
pub fn round_half_up(x: f32) -> f32 {
    (x + 0.5).floor()
}

/// Float → integer code (paper Eq. 1 numerator).
#[inline]
pub fn quantize_val(r: f32, n_frac: i32, n_bits: u32, unsigned: bool) -> i32 {
    let (qmin, qmax) = qrange(n_bits, unsigned);
    let scaled = round_half_up(r * exp2i(n_frac));
    // clamp in f32 space first to avoid i32 overflow on huge inputs
    scaled.clamp(qmin as f32, qmax as f32) as i32
}

/// Integer code → float (`r^q = r^I * 2^-N`).
#[inline]
pub fn dequantize_val(v: i32, n_frac: i32) -> f32 {
    v as f32 * exp2i(-n_frac)
}

/// `2^n` as f32 for |n| ≤ 126.
#[inline]
pub fn exp2i(n: i32) -> f32 {
    debug_assert!((-126..=126).contains(&n));
    f32::from_bits((((127 + n) as u32) << 23) & 0x7f80_0000)
}

/// The paper's `Q(r; N, n_bits)`: quantize then dequantize.
#[inline]
pub fn q(r: f32, n_frac: i32, n_bits: u32, unsigned: bool) -> f32 {
    dequantize_val(quantize_val(r, n_frac, n_bits, unsigned), n_frac)
}

/// Rounded arithmetic right shift for `s >= 0` (`floor(v/2^s + 0.5)`),
/// left shift for `s < 0`. This is the paper's Table-5 bit-shifting
/// operator.
#[inline]
pub fn shift_round(v: i32, s: i32) -> i32 {
    if s > 0 {
        let half = 1i32 << (s - 1);
        (v.wrapping_add(half)) >> s
    } else if s == 0 {
        v
    } else {
        v.wrapping_shl((-s) as u32)
    }
}

/// Alignment into the accumulator domain (bias / residual): left shift
/// for `s >= 0` (the common case — Eq. 3's `2^{(N_x+N_w)-N_b}`), rounded
/// right shift otherwise.
#[inline]
pub fn align(v: i32, s: i32) -> i32 {
    shift_round(v, -s)
}

/// Requantize an accumulator value: rounded shift then clamp
/// (unsigned range when the module ends in ReLU).
#[inline]
pub fn requantize_val(acc: i32, out_shift: i32, n_bits: u32, relu: bool) -> i32 {
    let (qmin, qmax) = qrange(n_bits, relu);
    shift_round(acc, out_shift).clamp(qmin, qmax)
}

// ---------------------------------------------------------------------
// Tensor-level helpers
// ---------------------------------------------------------------------

/// Quantize a whole f32 tensor to integer codes.
pub fn quantize_tensor(t: &Tensor, n_frac: i32, n_bits: u32, unsigned: bool) -> TensorI32 {
    t.map_i32(|x| quantize_val(x, n_frac, n_bits, unsigned))
}

/// Dequantize codes back to f32.
pub fn dequantize_tensor(t: &TensorI32, n_frac: i32) -> Tensor {
    let scale = exp2i(-n_frac);
    t.map_f32(|v| v as f32 * scale)
}

/// Requantize a whole accumulator tensor.
pub fn requantize_tensor(acc: &TensorI32, out_shift: i32, n_bits: u32, relu: bool) -> TensorI32 {
    let (qmin, qmax) = qrange(n_bits, relu);
    if out_shift > 0 {
        let half = 1i32 << (out_shift - 1);
        acc.map_i32_ref(|v| ((v.wrapping_add(half)) >> out_shift).clamp(qmin, qmax))
    } else if out_shift == 0 {
        acc.map_i32_ref(|v| v.clamp(qmin, qmax))
    } else {
        let sh = (-out_shift) as u32;
        acc.map_i32_ref(|v| v.wrapping_shl(sh).clamp(qmin, qmax))
    }
}

impl TensorI32 {
    /// Elementwise i32 → i32 map (kept here to keep tensor/ generic).
    pub fn map_i32_ref<F: Fn(i32) -> i32>(&self, f: F) -> TensorI32 {
        TensorI32 {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp2i_exact() {
        assert_eq!(exp2i(0), 1.0);
        assert_eq!(exp2i(5), 32.0);
        assert_eq!(exp2i(-3), 0.125);
        assert_eq!(exp2i(-20), (0.5f32).powi(20));
    }

    #[test]
    fn round_half_up_semantics() {
        // mirrors python/tests/test_quant_kernels.py
        let cases = [(-1.5, -1.0), (-0.5, 0.0), (0.49, 0.0), (0.5, 1.0), (2.5, 3.0)];
        for (x, want) in cases {
            assert_eq!(round_half_up(x), want, "x={x}");
        }
    }

    #[test]
    fn quantize_matches_eq1() {
        // r = 0.3, N = 5: round(0.3 * 32) = round(9.6) = 10
        assert_eq!(quantize_val(0.3, 5, 8, false), 10);
        assert_eq!(dequantize_val(10, 5), 0.3125);
        // saturation
        assert_eq!(quantize_val(100.0, 5, 8, false), 127);
        assert_eq!(quantize_val(-100.0, 5, 8, false), -128);
        // unsigned (post-ReLU) range
        assert_eq!(quantize_val(10.0, 5, 8, true), 255);
        assert_eq!(quantize_val(-1.0, 5, 8, true), 0);
    }

    #[test]
    fn negative_fractional_bits_select_upper_digits() {
        // N = -3: steps of 8 (paper §1.1)
        assert_eq!(q(12.0, -3, 8, false), 16.0);
        assert_eq!(q(20.0, -3, 8, false), 24.0);
        assert_eq!(q(100.0, -3, 8, false), 104.0);
    }

    #[test]
    fn shift_round_is_floor_half_up() {
        for v in [-1000i32, -17, -9, -8, -7, -1, 0, 1, 7, 8, 9, 1000] {
            for s in 0..12 {
                let want = ((v as f64) / f64::powi(2.0, s) + 0.5).floor() as i32;
                assert_eq!(shift_round(v, s), want, "v={v} s={s}");
            }
        }
        assert_eq!(shift_round(3, -2), 12); // left shift
    }

    #[test]
    fn align_is_inverse_direction() {
        assert_eq!(align(3, 2), 12);
        assert_eq!(align(12, -2), 3);
        assert_eq!(align(13, -2), 3); // 13/4 = 3.25 -> 3
        assert_eq!(align(14, -2), 4); // 3.5 -> 4 (half up)
    }

    #[test]
    fn requantize_ranges() {
        assert_eq!(requantize_val(1 << 20, 10, 8, false), 127);
        assert_eq!(requantize_val(-(1 << 20), 10, 8, false), -128);
        assert_eq!(requantize_val(-(1 << 20), 10, 8, true), 0);
        assert_eq!(requantize_val(130 << 4, 4, 8, true), 130);
    }

    #[test]
    fn tensor_roundtrip() {
        let t = Tensor::from_vec(&[4], vec![0.1, -0.7, 1.9, -3.2]);
        let q8 = quantize_tensor(&t, 5, 8, false);
        assert_eq!(q8.data, vec![3, -22, 61, -102]);
        let back = dequantize_tensor(&q8, 5);
        for (orig, rec) in t.data.iter().zip(&back.data) {
            assert!((orig - rec).abs() <= 0.5 / 32.0 + 1e-6);
        }
    }

    #[test]
    fn requantize_tensor_matches_scalar() {
        let acc = TensorI32::from_vec(&[6], vec![-5000, -7, 0, 7, 5000, 123456]);
        for s in [-2, 0, 3, 9] {
            let t = requantize_tensor(&acc, s, 8, false);
            for (i, &v) in acc.data.iter().enumerate() {
                assert_eq!(t.data[i], requantize_val(v, s, 8, false));
            }
        }
    }

    #[test]
    fn quantization_error_bounded_by_half_step() {
        // within the representable range, |r - Q(r)| <= 2^-N / 2
        let mut rng = crate::util::rng::Pcg::new(9);
        for _ in 0..1000 {
            let r = rng.uniform(-3.9, 3.9);
            let e = (r - q(r, 5, 8, false)).abs();
            assert!(e <= 0.5 * exp2i(-5) + 1e-6, "r={r} e={e}");
        }
    }
}
