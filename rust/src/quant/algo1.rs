//! Algorithm 1 — per-module grid search for the optimal fractional bits
//! `(N_w, N_b, N_o)` minimising the reconstruction error
//! `‖O − Q(CONV(X, W, B); N_o)‖₂` (Eq. 5).
//!
//! The search space is narrowed as in the paper: the largest useful
//! integer-bit count for a tensor is `ceil(log2(max|·| + 1)) + 1`
//! (Eq. 6) and the window scans τ positions below it; `N = (n_bits−1) − i`
//! converts integer bits `i` to fractional bits.
//!
//! Cost structure (an optimization over the naive τ³ loop, numerically
//! identical): the conv accumulator depends only on `N_w`, the bias
//! addition only on `(N_w, N_b)`, the requantization only on everything —
//! so the inner loops reuse the accumulator, making the search
//! `O(τ·Γ + τ³·|O|)` instead of `O(τ³·Γ)` (Γ = conv cost). The
//! candidates for a given `N_w` can also be evaluated on independent
//! threads (see `coordinator::calib`).

use crate::graph::{ModuleKind, UnifiedModule};
use crate::quant::params::ModuleShifts;
use crate::quant::scheme;
use crate::tensor::im2col::Padding;
use crate::tensor::{ops_int, Tensor, TensorI32};
use crate::util::mathutil::magnitude_bits;

/// Search window for one tensor: fractional-bit candidates, highest
/// precision first.
pub fn frac_window(max_abs: f32, n_bits: u32, tau: i32) -> Vec<i32> {
    let mag = magnitude_bits(max_abs);
    let base = (n_bits as i32 - 1) - mag;
    (0..=tau).map(|d| base + d).collect()
}

/// Inputs to the per-module search.
pub struct ModuleProblem<'a> {
    /// the module being calibrated
    pub module: &'a UnifiedModule,
    /// quantized input codes (from the already-calibrated prefix)
    pub x_int: &'a TensorI32,
    /// fractional bits of `x_int`
    pub n_x: i32,
    /// folded FP weights
    pub w: &'a Tensor,
    /// folded FP bias
    pub b: &'a [f32],
    /// residual codes + their fractional bits (Fig. 1 c/d)
    pub res: Option<(&'a TensorI32, i32)>,
    /// FP target activations `O` (Eq. 5)
    pub target: &'a Tensor,
}

/// Search configuration.
#[derive(Clone, Copy, Debug)]
pub struct SearchConfig {
    /// bit-width (8 in the paper's main results)
    pub n_bits: u32,
    /// window width τ (paper: 4)
    pub tau: i32,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig { n_bits: 8, tau: 4 }
    }
}

/// Result of the search.
#[derive(Clone, Copy, Debug)]
pub struct SearchResult {
    /// winning fractional bits
    pub shifts: ModuleShifts,
    /// achieved ‖O − O^q‖₂
    pub error: f64,
    /// number of (N_w, N_b, N_o) candidates evaluated
    pub evaluated: usize,
}

/// im2col'd input patches, shared by every `N_w` branch (the conv's
/// geometry never changes inside the search — hoisting this was §Perf
/// iteration #3).
fn prepare_patches(m: &UnifiedModule, x_int: &TensorI32) -> TensorI32 {
    match &m.kind {
        ModuleKind::Conv { kh, kw, stride, .. } => {
            crate::tensor::im2col::im2col(x_int, *kh, *kw, *stride, Padding::Same).0
        }
        ModuleKind::Dense { .. } => x_int.reshape(&[
            x_int.shape.dim(0),
            x_int.numel() / x_int.shape.dim(0),
        ]),
        ModuleKind::Gap => panic!("gap modules have no parameters to search"),
    }
}

/// Accumulator from prepared patches: a plain GEMM for both kinds.
fn accumulate(m: &UnifiedModule, patches: &TensorI32, w_codes: &TensorI32) -> Vec<i32> {
    let (mrows, k) = (patches.shape.dim(0), patches.shape.dim(1));
    let cout = *w_codes.shape.dims().last().unwrap();
    match &m.kind {
        ModuleKind::Conv { kh, kw, cin, .. } => {
            debug_assert_eq!(k, kh * kw * cin);
            let wmat = &w_codes.data; // HWIO flattens to (kh*kw*cin, cout)
            ops_int::gemm_i32(&patches.data, wmat, mrows, k, cout)
        }
        ModuleKind::Dense { .. } => {
            ops_int::gemm_i32(&patches.data, &w_codes.data, mrows, k, cout)
        }
        ModuleKind::Gap => unreachable!(),
    }
}

/// Evaluate one `N_w` branch of the grid (the unit of parallelism the
/// coordinator fans across workers): the conv accumulator is computed
/// once, then all `(N_b, N_o)` pairs are scored against it.
pub fn search_nw(p: &ModuleProblem<'_>, cfg: SearchConfig, n_w: i32) -> SearchResult {
    let patches = prepare_patches(p.module, p.x_int);
    search_nw_prepared(p, &patches, cfg, n_w)
}

/// `search_nw` over pre-extracted patches (see [`search`], which hoists
/// the im2col out of the `N_w` loop).
pub fn search_nw_prepared(
    p: &ModuleProblem<'_>,
    patches: &TensorI32,
    cfg: SearchConfig,
    n_w: i32,
) -> SearchResult {
    let b_max = p.b.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    let o_max = p.target.max_abs();
    let b_cands = frac_window(b_max, cfg.n_bits, cfg.tau);
    let o_cands = frac_window(o_max, cfg.n_bits, cfg.tau);
    let w_codes = scheme::quantize_tensor(p.w, n_w, cfg.n_bits, false);
    let acc0 = accumulate(p.module, patches, &w_codes);
    // pre-align the residual once per N_w (it depends on N_w via the
    // accumulator scale 2^-(N_x+N_w))
    let res_acc: Option<Vec<i32>> = p.res.map(|(rt, n_r)| {
        let rs = p.n_x + n_w - n_r;
        rt.data.iter().map(|&v| scheme::align(v, rs)).collect()
    });
    let mut best: Option<SearchResult> = None;
    let mut evaluated = 0usize;
    let mut acc = vec![0i32; acc0.len()];
    for &n_b in &b_cands {
        let sp_bias = p.n_x + n_w - n_b;
        let b_codes: Vec<i32> = p
            .b
            .iter()
            .map(|&x| scheme::quantize_val(x, n_b, cfg.n_bits, false))
            .collect();
        let aligned: Vec<i32> =
            b_codes.iter().map(|&v| scheme::align(v, sp_bias)).collect();
        let cout = aligned.len();
        // acc = acc0 + bias (+ residual), reusing one scratch buffer
        acc.copy_from_slice(&acc0);
        for (row, chunk) in acc.chunks_exact_mut(cout).enumerate() {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = v.wrapping_add(aligned[j]);
                if let Some(r) = &res_acc {
                    *v = v.wrapping_add(r[row * cout + j]);
                }
            }
        }
        // score every N_o in ONE pass over the accumulator (the error
        // loop is memory-bound; §Perf iteration #4)
        let errs = recon_errors_multi(
            &acc,
            &o_cands,
            p.n_x + n_w,
            cfg.n_bits,
            p.module.relu,
            &p.target.data,
        );
        for (&n_o, &err) in o_cands.iter().zip(&errs) {
            evaluated += 1;
            if best.map(|b| err < b.error).unwrap_or(true) {
                best = Some(SearchResult {
                    shifts: ModuleShifts { n_w, n_b, n_o },
                    error: err,
                    evaluated: 0,
                });
            }
        }
    }
    let mut r = best.expect("non-empty search space");
    r.evaluated = evaluated;
    r
}

/// The `N_w` candidate list for a problem.
pub fn weight_candidates(p: &ModuleProblem<'_>, cfg: SearchConfig) -> Vec<i32> {
    frac_window(p.w.max_abs(), cfg.n_bits, cfg.tau)
}

/// Run Algorithm 1 for one module (serial over the `N_w` branches; the
/// coordinator's parallel variant fans `search_nw` across a pool).
pub fn search(p: &ModuleProblem<'_>, cfg: SearchConfig) -> SearchResult {
    let patches = prepare_patches(p.module, p.x_int);
    let mut best: Option<SearchResult> = None;
    let mut evaluated = 0usize;
    for n_w in weight_candidates(p, cfg) {
        let r = search_nw_prepared(p, &patches, cfg, n_w);
        evaluated += r.evaluated;
        if best.map(|b| r.error < b.error).unwrap_or(true) {
            best = Some(r);
        }
    }
    let mut r = best.expect("non-empty search space");
    r.evaluated = evaluated;
    r
}

/// ‖O − deq(requant(acc))‖₂ without materialising the dequantized
/// tensor. Reference implementation — the hot path uses
/// [`recon_errors_multi`]; a unit test pins the two together.
#[cfg(test)]
fn recon_error(
    acc: &[i32],
    out_shift: i32,
    n_o: i32,
    n_bits: u32,
    relu: bool,
    target: &[f32],
) -> f64 {
    debug_assert_eq!(acc.len(), target.len());
    let (qmin, qmax) = scheme::qrange(n_bits, relu);
    let scale = scheme::exp2i(-n_o);
    let mut sum = 0.0f64;
    for (&a, &t) in acc.iter().zip(target) {
        let code = scheme::shift_round(a, out_shift).clamp(qmin, qmax);
        let d = (code as f32 * scale - t) as f64;
        sum += d * d;
    }
    sum.sqrt()
}

/// All `N_o` candidates scored in a single pass over the accumulator
/// (identical numerics to calling [`recon_error`] per candidate; the
/// error loop is memory-bound, so reading `acc`/`target` once for all
/// candidates is ~`len(o_cands)`× cheaper).
fn recon_errors_multi(
    acc: &[i32],
    o_cands: &[i32],
    nx_plus_nw: i32,
    n_bits: u32,
    relu: bool,
    target: &[f32],
) -> Vec<f64> {
    debug_assert_eq!(acc.len(), target.len());
    let (qmin, qmax) = scheme::qrange(n_bits, relu);
    let params: Vec<(i32, f32)> = o_cands
        .iter()
        .map(|&n_o| (nx_plus_nw - n_o, scheme::exp2i(-n_o)))
        .collect();
    let mut sums = vec![0.0f64; o_cands.len()];
    for (&a, &t) in acc.iter().zip(target) {
        for (k, &(out_shift, scale)) in params.iter().enumerate() {
            let code = scheme::shift_round(a, out_shift).clamp(qmin, qmax);
            let d = (code as f32 * scale - t) as f64;
            sums[k] += d * d;
        }
    }
    sums.into_iter().map(f64::sqrt).collect()
}

/// Pick the fractional bits for the *graph input* by pure quantization
/// error (the input has no conv to absorb error into).
pub fn search_input_frac(x: &Tensor, n_bits: u32, tau: i32) -> i32 {
    let cands = frac_window(x.max_abs(), n_bits, tau);
    let mut best = (f64::INFINITY, cands[0]);
    for &n in &cands {
        let mut err = 0.0f64;
        for &v in &x.data {
            let d = (scheme::q(v, n, n_bits, false) - v) as f64;
            err += d * d;
        }
        if err < best.0 {
            best = (err, n);
        }
    }
    best.1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::UnifiedModule;

    #[test]
    fn window_matches_paper_lines_3_to_5() {
        // max|W| = 0.9 -> mag = 2 -> N in [7-2 .. 7-2+4] = [5..9]
        assert_eq!(frac_window(0.9, 8, 4), vec![5, 6, 7, 8, 9]);
        // max|O| = 20 -> mag = ceil(log2 21)+1 = 6 -> N in [1..5]
        assert_eq!(frac_window(20.0, 8, 4), vec![1, 2, 3, 4, 5]);
    }

    fn conv_module(relu: bool, res: bool) -> UnifiedModule {
        UnifiedModule {
            name: "c".into(),
            kind: ModuleKind::Conv { kh: 3, kw: 3, cin: 2, cout: 3, stride: 1 },
            src: "input".into(),
            res: if res { Some("r".into()) } else { None },
            relu,
        }
    }

    /// Build a random problem whose FP target comes from the real float
    /// conv, so the search has a meaningful optimum.
    fn random_problem(
        rng: &mut crate::util::rng::Pcg,
        relu: bool,
    ) -> (UnifiedModule, Tensor, TensorI32, Tensor, Vec<f32>, Tensor) {
        let m = conv_module(relu, false);
        let x = Tensor::from_vec(&[1, 6, 6, 2], (0..72).map(|_| rng.normal()).collect());
        let n_x = 5;
        let x_int = scheme::quantize_tensor(&x, n_x, 8, false);
        let w = Tensor::from_vec(&[3, 3, 2, 3], (0..54).map(|_| rng.normal_ms(0.0, 0.4)).collect());
        let b: Vec<f32> = (0..3).map(|_| rng.normal_ms(0.0, 0.2)).collect();
        // FP target from the dequantized input (matching what the joint
        // calibrator feeds) — keeps the testable error floor tiny
        let xq = scheme::dequantize_tensor(&x_int, n_x);
        let mut t = crate::tensor::ops::conv2d(&xq, &w, &b, 1, Padding::Same);
        if relu {
            crate::tensor::ops::relu_inplace(&mut t);
        }
        (m, x, x_int, w, b, t)
    }

    #[test]
    fn search_finds_low_error_solution() {
        let mut rng = crate::util::rng::Pcg::new(21);
        for relu in [false, true] {
            let (m, _x, x_int, w, b, target) = random_problem(&mut rng, relu);
            let p = ModuleProblem {
                module: &m,
                x_int: &x_int,
                n_x: 5,
                w: &w,
                b: &b,
                res: None,
                target: &target,
            };
            let r = search(&p, SearchConfig::default());
            assert_eq!(r.evaluated, 125); // (τ+1)^3
            // relative error under 5%
            let tnorm = target.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
            assert!(r.error < 0.05 * tnorm.max(1e-9), "err {} vs {}", r.error, tnorm);
        }
    }

    #[test]
    fn search_beats_window_edges() {
        // the winning candidate must be at least as good as both window
        // extremes evaluated directly
        let mut rng = crate::util::rng::Pcg::new(22);
        let (m, _x, x_int, w, b, target) = random_problem(&mut rng, false);
        let p = ModuleProblem {
            module: &m,
            x_int: &x_int,
            n_x: 5,
            w: &w,
            b: &b,
            res: None,
            target: &target,
        };
        let full = search(&p, SearchConfig::default());
        let narrow = search(&p, SearchConfig { n_bits: 8, tau: 0 });
        assert!(full.error <= narrow.error + 1e-9);
    }

    #[test]
    fn input_frac_prefers_high_precision_for_small_values() {
        // irrational-step values in [-0.5, 0.5): not exactly representable
        // at any candidate N, so finer scales strictly reduce error until
        // clipping kicks in at N = 9 (0.5 * 512 > 127).
        let x = Tensor::from_vec(
            &[64],
            (0..64)
                .map(|i| ((i as f32 * 0.7548776) % 1.0) - 0.5)
                .collect(),
        );
        let n = search_input_frac(&x, 8, 4);
        assert_eq!(n, 8, "n = {n}");
    }

    #[test]
    fn residual_problem_accounts_for_shortcut() {
        let mut rng = crate::util::rng::Pcg::new(23);
        let m = conv_module(true, true);
        let x = Tensor::from_vec(&[1, 4, 4, 2], (0..32).map(|_| rng.normal()).collect());
        let x_int = scheme::quantize_tensor(&x, 5, 8, false);
        let w = Tensor::from_vec(&[3, 3, 2, 3], (0..54).map(|_| rng.normal_ms(0.0, 0.3)).collect());
        let b = vec![0.0f32; 3];
        let res_f = Tensor::from_vec(&[1, 4, 4, 3], (0..48).map(|_| rng.uniform(0.0, 2.0)).collect());
        let res_int = scheme::quantize_tensor(&res_f, 6, 8, true);
        let xq = scheme::dequantize_tensor(&x_int, 5);
        let rq = scheme::dequantize_tensor(&res_int, 6);
        let conv = crate::tensor::ops::conv2d(&xq, &w, &b, 1, Padding::Same);
        let mut t = crate::tensor::ops::add(&conv, &rq);
        crate::tensor::ops::relu_inplace(&mut t);
        let p = ModuleProblem {
            module: &m,
            x_int: &x_int,
            n_x: 5,
            w: &w,
            b: &b,
            res: Some((&res_int, 6)),
            target: &t,
        };
        let r = search(&p, SearchConfig::default());
        let tnorm = t.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
        assert!(r.error < 0.08 * tnorm.max(1e-9), "err {} / {}", r.error, tnorm);
    }
}

#[cfg(test)]
mod perf_equivalence_tests {
    use super::*;

    #[test]
    fn multi_candidate_scoring_matches_reference() {
        let mut rng = crate::util::rng::Pcg::new(55);
        let acc: Vec<i32> = (0..512)
            .map(|_| rng.int_range(-(1 << 22), 1 << 22) as i32)
            .collect();
        let target: Vec<f32> = (0..512).map(|_| rng.normal_ms(0.0, 4.0)).collect();
        let o_cands = vec![2, 3, 4, 5, 6];
        let nx_nw = 12;
        for relu in [false, true] {
            let multi = recon_errors_multi(&acc, &o_cands, nx_nw, 8, relu, &target);
            for (k, &n_o) in o_cands.iter().enumerate() {
                let single = recon_error(&acc, nx_nw - n_o, n_o, 8, relu, &target);
                assert!(
                    (multi[k] - single).abs() < 1e-9 * (1.0 + single),
                    "n_o={n_o}: {} vs {}",
                    multi[k],
                    single
                );
            }
        }
    }
}
