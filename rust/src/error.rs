//! The crate-wide typed error — `thiserror`-style by hand (the offline
//! registry has no proc-macro crates), cloneable so the serving layer
//! can fan one backend failure out to every waiting request.
//!
//! Every public fallible API in `graph/`, `quant/`, `runtime/`, `data/`,
//! `coordinator/` and [`crate::session`] returns [`DfqError`]. The one
//! deliberate exception is [`crate::util::json`], whose parser keeps
//! plain `String` errors (it is self-contained infrastructure); callers
//! classify those as [`DfqError::Manifest`] at the boundary — which is
//! what the blanket `From<String>` impl below does.

use std::fmt;

/// What went wrong, by pipeline layer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DfqError {
    /// A filesystem operation failed.
    Io {
        /// what the operation was doing (usually includes the path)
        context: String,
        /// the stringified `std::io::Error`
        message: String,
    },
    /// The artifact manifest or a serialized spec could not be parsed.
    Manifest(String),
    /// A dataflow graph is invalid or contains unfusable patterns.
    Graph(String),
    /// A dataset / weight container is malformed or incomplete.
    Data(String),
    /// The PJRT runtime is unavailable, or compiling/executing an AOT
    /// artifact failed.
    Runtime(String),
    /// The serving pipeline failed (service stopped, batch dropped).
    Serve(String),
    /// A model's admission queue is full — the request was rejected
    /// instead of growing the queue without bound. Back off and retry.
    Overloaded {
        /// the model whose queue was saturated
        model: String,
        /// the configured admission-queue depth that was exceeded
        depth: usize,
    },
    /// User-supplied configuration is invalid.
    InvalidInput(String),
    /// A `dfq::wire` protocol violation or transport failure, by
    /// [`WireFault`] kind — what a `dfq serve --listen` server or a
    /// [`crate::wire::WireClient`] reports when a peer sends garbage,
    /// truncates a frame, or the socket fails.
    Wire {
        /// the protocol-level fault class
        fault: WireFault,
        /// human-readable detail
        message: String,
    },
    /// The static plan verifier ([`crate::analysis`]) rejected a
    /// compiled `ExecPlan`: an intermediate can overflow i32, a shift
    /// or clamp constant is unsound, or the buffer-slot schedule is
    /// unsafe. Addressed to the offending step.
    Verify {
        /// the contract class that failed
        kind: PlanFaultKind,
        /// index of the offending plan step
        step: usize,
        /// name of the module the step lowers
        module: String,
        /// the derivation: which constant, which bound, which values
        message: String,
    },
}

/// How a wire frame (or the stream carrying it) was invalid. Carried by
/// [`DfqError::Wire`]; every decoder rejection is one of these, so tests
/// and retry policies can match on the class instead of parsing strings.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireFault {
    /// the frame did not start with the `dfq1` magic bytes
    BadMagic,
    /// the peer speaks a different protocol version
    BadVersion,
    /// an unknown frame-type byte
    UnknownFrame,
    /// the stream ended (or stalled past its budget) inside a frame
    Truncated,
    /// the declared payload length exceeds the hard frame-size cap
    Oversized,
    /// the payload bytes do not parse as the declared frame type
    Malformed,
    /// a socket-level failure (connect, read, write, timeout)
    Io,
}

impl WireFault {
    /// Stable one-word label (used in `Display` and on the wire).
    pub fn label(&self) -> &'static str {
        match self {
            WireFault::BadMagic => "bad-magic",
            WireFault::BadVersion => "bad-version",
            WireFault::UnknownFrame => "unknown-frame",
            WireFault::Truncated => "truncated",
            WireFault::Oversized => "oversized",
            WireFault::Malformed => "malformed",
            WireFault::Io => "io",
        }
    }

    /// Stable numeric code for the wire encoding of error frames.
    pub fn code(&self) -> u32 {
        match self {
            WireFault::BadMagic => 1,
            WireFault::BadVersion => 2,
            WireFault::UnknownFrame => 3,
            WireFault::Truncated => 4,
            WireFault::Oversized => 5,
            WireFault::Malformed => 6,
            WireFault::Io => 7,
        }
    }

    /// Inverse of [`WireFault::code`] (`None` for unknown codes, so a
    /// newer peer's fault class degrades gracefully).
    pub fn from_code(code: u32) -> Option<WireFault> {
        Some(match code {
            1 => WireFault::BadMagic,
            2 => WireFault::BadVersion,
            3 => WireFault::UnknownFrame,
            4 => WireFault::Truncated,
            5 => WireFault::Oversized,
            6 => WireFault::Malformed,
            7 => WireFault::Io,
            _ => return None,
        })
    }
}

/// Which machine-checked plan contract a step violated. Carried by
/// [`DfqError::Verify`] and by [`crate::analysis::PlanFault`]; the
/// corrupt-plan corpus matches on the class instead of parsing strings
/// (mirroring [`WireFault`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanFaultKind {
    /// an accumulator / bias-add / residual-add can exceed i32
    AccOverflow,
    /// a shift constant's magnitude is at or beyond the 32-bit width
    ShiftOutOfWidth,
    /// a right shift large enough to collapse the whole incoming value
    /// range to zero — every bit of signal is destroyed
    PrecisionLoss,
    /// a clamp range is inverted or not a subset of its target dtype
    ClampRange,
    /// a step writes a slot that still holds a live value
    SlotOverlap,
    /// a step (or the plan output) reads a slot nothing has written
    ReadBeforeWrite,
    /// a value is produced (or released) without ever being consumed
    DeadStep,
    /// a step addresses a slot outside the plan's `slot_count`
    SlotBounds,
    /// a step's packed weight storage is narrower than the calibrated
    /// bit-range licenses — codes could truncate at bind time
    PackWidth,
    /// the audit census refutes the paper's dataflow hypothesis for
    /// this plan: the fused schedule does not perform strictly fewer
    /// quantization ops than the unfused ablation
    AuditQuantOps,
}

impl PlanFaultKind {
    /// Stable kebab-case label (used in `Display` and `--json` output).
    pub fn label(&self) -> &'static str {
        match self {
            PlanFaultKind::AccOverflow => "acc-overflow",
            PlanFaultKind::ShiftOutOfWidth => "shift-out-of-width",
            PlanFaultKind::PrecisionLoss => "precision-loss",
            PlanFaultKind::ClampRange => "clamp-range",
            PlanFaultKind::SlotOverlap => "slot-overlap",
            PlanFaultKind::ReadBeforeWrite => "read-before-write",
            PlanFaultKind::DeadStep => "dead-step",
            PlanFaultKind::SlotBounds => "slot-bounds",
            PlanFaultKind::PackWidth => "pack-width",
            PlanFaultKind::AuditQuantOps => "audit-quant-ops",
        }
    }
}

impl DfqError {
    /// An I/O failure with the operation it interrupted.
    pub fn io(context: impl Into<String>, source: &std::io::Error) -> DfqError {
        DfqError::Io { context: context.into(), message: source.to_string() }
    }

    /// A manifest / serialized-spec parse failure.
    pub fn manifest(msg: impl Into<String>) -> DfqError {
        DfqError::Manifest(msg.into())
    }

    /// An invalid or unfusable dataflow graph.
    pub fn graph(msg: impl Into<String>) -> DfqError {
        DfqError::Graph(msg.into())
    }

    /// A malformed dataset or weight container.
    pub fn data(msg: impl Into<String>) -> DfqError {
        DfqError::Data(msg.into())
    }

    /// A PJRT runtime failure.
    pub fn runtime(msg: impl Into<String>) -> DfqError {
        DfqError::Runtime(msg.into())
    }

    /// A serving-pipeline failure.
    pub fn serve(msg: impl Into<String>) -> DfqError {
        DfqError::Serve(msg.into())
    }

    /// An admission-control rejection: the named model's bounded queue
    /// is full.
    pub fn overloaded(model: impl Into<String>, depth: usize) -> DfqError {
        DfqError::Overloaded { model: model.into(), depth }
    }

    /// Invalid user input / configuration.
    pub fn invalid(msg: impl Into<String>) -> DfqError {
        DfqError::InvalidInput(msg.into())
    }

    /// A wire-protocol violation or transport failure.
    pub fn wire(fault: WireFault, msg: impl Into<String>) -> DfqError {
        DfqError::Wire { fault, message: msg.into() }
    }

    /// A static plan-verification fault, addressed to one plan step.
    pub fn verify(
        kind: PlanFaultKind,
        step: usize,
        module: impl Into<String>,
        msg: impl Into<String>,
    ) -> DfqError {
        DfqError::Verify { kind, step, module: module.into(), message: msg.into() }
    }
}

impl fmt::Display for DfqError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DfqError::Io { context, message } => write!(f, "{context}: {message}"),
            DfqError::Manifest(m) => write!(f, "manifest/spec: {m}"),
            DfqError::Graph(m) => write!(f, "graph: {m}"),
            DfqError::Data(m) => write!(f, "data: {m}"),
            DfqError::Runtime(m) => write!(f, "runtime: {m}"),
            DfqError::Serve(m) => write!(f, "serve: {m}"),
            DfqError::Overloaded { model, depth } => write!(
                f,
                "overloaded: model '{model}' admission queue is full (depth {depth})"
            ),
            DfqError::InvalidInput(m) => write!(f, "invalid input: {m}"),
            DfqError::Wire { fault, message } => {
                write!(f, "wire/{}: {message}", fault.label())
            }
            DfqError::Verify { kind, step, module, message } => write!(
                f,
                "verify/{}: step {step} ({module}): {message}",
                kind.label()
            ),
        }
    }
}

impl std::error::Error for DfqError {}

/// `util::json` (and only it) reports `String` errors; everywhere the
/// JSON layer is used the payload is the artifact manifest or a
/// serialized spec, so the boundary conversion classifies as
/// [`DfqError::Manifest`].
impl From<String> for DfqError {
    fn from(msg: String) -> DfqError {
        DfqError::Manifest(msg)
    }
}

/// See the `From<String>` impl — same classification for `&str`
/// (`Option::ok_or` sites in manifest plumbing).
impl From<&str> for DfqError {
    fn from(msg: &str) -> DfqError {
        DfqError::Manifest(msg.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_prefixed_by_layer() {
        assert_eq!(
            DfqError::graph("cycle at c0").to_string(),
            "graph: cycle at c0"
        );
        let e = DfqError::io(
            "read artifacts/manifest.json",
            &std::io::Error::new(std::io::ErrorKind::NotFound, "gone"),
        );
        assert!(e.to_string().contains("read artifacts/manifest.json"));
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn json_string_errors_classify_as_manifest() {
        let e: DfqError = String::from("missing key 'spec'").into();
        assert_eq!(e, DfqError::Manifest("missing key 'spec'".into()));
        let e: DfqError = "weights path".into();
        assert!(matches!(e, DfqError::Manifest(_)));
    }

    #[test]
    fn overloaded_names_model_and_depth() {
        let e = DfqError::overloaded("resnet_s", 64);
        assert_eq!(e, DfqError::Overloaded { model: "resnet_s".into(), depth: 64 });
        assert!(e.to_string().contains("resnet_s"));
        assert!(e.to_string().contains("64"));
    }

    #[test]
    fn wire_fault_codes_roundtrip() {
        for fault in [
            WireFault::BadMagic,
            WireFault::BadVersion,
            WireFault::UnknownFrame,
            WireFault::Truncated,
            WireFault::Oversized,
            WireFault::Malformed,
            WireFault::Io,
        ] {
            assert_eq!(WireFault::from_code(fault.code()), Some(fault));
        }
        assert_eq!(WireFault::from_code(0), None);
        assert_eq!(WireFault::from_code(999), None);
        let e = DfqError::wire(WireFault::Oversized, "payload 99MB > cap");
        assert!(e.to_string().contains("oversized"), "{e}");
        assert!(e.to_string().contains("99MB"), "{e}");
    }

    #[test]
    fn verify_faults_name_kind_step_and_module() {
        let e = DfqError::verify(
            PlanFaultKind::AccOverflow,
            3,
            "c1",
            "accumulator peak 3000000000 exceeds i32::MAX",
        );
        let s = e.to_string();
        assert!(s.starts_with("verify/acc-overflow"), "{s}");
        assert!(s.contains("step 3"), "{s}");
        assert!(s.contains("(c1)"), "{s}");
        // every kind has a distinct stable label
        let kinds = [
            PlanFaultKind::AccOverflow,
            PlanFaultKind::ShiftOutOfWidth,
            PlanFaultKind::PrecisionLoss,
            PlanFaultKind::ClampRange,
            PlanFaultKind::SlotOverlap,
            PlanFaultKind::ReadBeforeWrite,
            PlanFaultKind::DeadStep,
            PlanFaultKind::SlotBounds,
            PlanFaultKind::PackWidth,
            PlanFaultKind::AuditQuantOps,
        ];
        let labels: std::collections::HashSet<&str> =
            kinds.iter().map(|k| k.label()).collect();
        assert_eq!(labels.len(), kinds.len());
    }

    #[test]
    fn errors_are_cloneable_for_fanout() {
        let e = DfqError::runtime("backend died");
        let copies = vec![e.clone(), e.clone(), e];
        assert!(copies.iter().all(|c| c.to_string().contains("backend died")));
    }
}
