//! Inference engines over the unified-module graph:
//!
//! * [`fp`] — the floating-point oracle (folded weights), supplying the
//!   Eq.-5 calibration targets and the FP rows of Tables 1/3/4;
//! * [`int`] — the integer-only engine (Eq. 3–4): i8-range codes, i32
//!   accumulation, shift-based alignment/requantization. Models the
//!   paper's custom hardware unit bit-exactly — cross-validated against
//!   the Pallas kernels via the PJRT artifacts in the integration tests.
//!   Executes with an activation-liveness pass and a reusable scratch
//!   arena ([`int::Scratch`]); the session layer adds batch-level data
//!   parallelism on top (`EngineKind::Int { threads }`), bit-identical
//!   for every thread count.

pub mod fp;
pub mod int;
