//! Inference engines over the unified-module graph, all executing one
//! compiled IR:
//!
//! * [`plan`] — the flat **`ExecPlan`**: the graph lowered once into
//!   shape-resolved steps over statically assigned buffer slots, with
//!   every name lookup, shape check, `Gap` power-of-two validation and
//!   quantization constant resolved at `compile()` time;
//! * [`exec`] — the generic plan executor (one [`exec::Scratch`] arena
//!   per in-flight pass) and the two kernel domains it runs:
//!   `i32` (Eq. 3–4) and `f32`;
//! * [`fp`] — the floating-point oracle (folded weights), supplying the
//!   Eq.-5 calibration targets and the FP rows of Tables 1/3/4;
//! * [`int`] — the integer-only engine (Eq. 3–4): i8-range codes, i32
//!   accumulation, shift-based alignment/requantization. Models the
//!   paper's custom hardware unit bit-exactly — cross-validated against
//!   the Pallas kernels via the PJRT artifacts in the integration tests.
//!
//! Both engines are thin executors over the same lowering path, so the
//! numeric domains cannot drift; the session layer adds batch-level data
//! parallelism on top (`EngineKind::Int { threads }`) over **cached**
//! plans, bit-identical for every thread count.

pub mod exec;
pub mod fp;
pub mod int;
pub mod plan;
