//! The flat **`ExecPlan` IR** — the unified-module graph lowered once
//! into a shape-resolved, statically-buffered schedule that both the
//! floating-point and the integer engine execute.
//!
//! The paper restructures the network into unified modules so the whole
//! dataflow can be optimized as one object; this module is the runtime
//! mirror of that move. [`ExecPlan::compile`] walks the graph **once**
//! and produces a `Vec` of steps in which
//!
//! * every `src`/`res` **name is resolved** to a buffer-slot index,
//! * every **shape is resolved** (conv geometry, dense fan-in, pooling
//!   windows) for the declared input resolution — only the batch
//!   dimension stays dynamic,
//! * every **quantization constant** (bias/out/residual shifts, clamp
//!   ranges, the `Gap` power-of-two shift) is folded in from the
//!   calibrated [`QuantSpec`], and
//! * **buffer slots** are assigned by an activation-liveness pass, so an
//!   executor needs exactly `slot_count` live buffers (one arena per
//!   in-flight pass) instead of a name-keyed map of every activation, and
//! * a **kernel variant** is selected per GEMM step ([`KernelChoice`]):
//!   integer plans emit the packed fused-epilogue kernel
//!   ([`crate::tensor::kernels`]) with the storage width the calibrated
//!   bit-range licenses, and 1×1 stride-1 convs elide im2col entirely
//!   (the patch matrix is the input buffer).
//!
//! All graph/spec validation errors — a spec that doesn't cover a
//! module, a dangling `src`/`res`, a residual shape mismatch, a
//! non-power-of-two pooling window, a conv over a flat activation —
//! surface here as typed [`DfqError`]s, **at compile time**. The
//! executors in [`crate::engine::exec`] perform no name or shape
//! resolution on the hot path.
//!
//! The same plan drives both numeric domains: [`ExecPlan::compile`]
//! resolves the integer epilogue constants, [`ExecPlan::compile_fp`]
//! lowers the identical schedule without them for the f32 oracle.
//! Later scaling layers (multi-node sharding, NUMA pinning, fused-kernel
//! emission) target this IR rather than re-walking the graph.

use std::collections::HashMap;

use crate::error::DfqError;
use crate::graph::{Graph, ModuleKind};
use crate::quant::params::QuantSpec;
use crate::quant::scheme;
use crate::tensor::im2col::{conv_geometry, Padding};
use crate::tensor::kernels::PackDtype;

/// Per-image shape of a value in the plan (the batch dimension is the
/// executor's runtime parameter).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ValShape {
    /// A spatial NHWC activation: per-image `h × w × c`.
    Spatial {
        /// height
        h: usize,
        /// width
        w: usize,
        /// channels
        c: usize,
    },
    /// A flat feature vector (dense / pooled output).
    Flat {
        /// features per image
        features: usize,
    },
}

impl ValShape {
    /// Elements per image.
    pub fn elems(&self) -> usize {
        match *self {
            ValShape::Spatial { h, w, c } => h * w * c,
            ValShape::Flat { features } => features,
        }
    }

    /// Full tensor dims for a batch of `n`.
    pub(crate) fn dims(&self, n: usize) -> Vec<usize> {
        match *self {
            ValShape::Spatial { h, w, c } => vec![n, h, w, c],
            ValShape::Flat { features } => vec![n, features],
        }
    }
}

impl std::fmt::Display for ValShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            ValShape::Spatial { h, w, c } => write!(f, "{h}x{w}x{c}"),
            ValShape::Flat { features } => write!(f, "{features}"),
        }
    }
}

/// Integer epilogue constants of one weighted step, fully resolved from
/// the calibrated spec at compile time (Eq. 3–4).
#[derive(Clone, Copy, Debug)]
pub(crate) struct QuantEpi {
    /// bias alignment shift `(N_x + N_w) − N_b` (left shift when ≥ 0)
    pub bias_shift: i32,
    /// output requantization shift `(N_x + N_w) − N_o`
    pub out_shift: i32,
    /// residual alignment shift `(N_x + N_w) − N_r` (0 when unused)
    pub res_shift: i32,
    /// output clamp range (unsigned after a fused ReLU)
    pub qmin: i32,
    /// see `qmin`
    pub qmax: i32,
    /// the unfused-ablation epilogue, when compiled with `pre_frac`
    pub unfused: Option<UnfusedEpi>,
}

impl QuantEpi {
    /// Resolve the full integer epilogue for one weighted module from
    /// the calibrated spec — the ONE place the Eq. 3–4 shift/clamp
    /// algebra is folded. Used by both the plan compiler and the
    /// per-module interpreter path, so the two cannot drift.
    pub(crate) fn resolve(
        spec: &QuantSpec,
        graph: &Graph,
        m: &crate::graph::UnifiedModule,
        pre_frac: Option<&HashMap<String, i32>>,
    ) -> Result<QuantEpi, DfqError> {
        let sp = spec.try_module(&m.name)?;
        let n_x = spec.try_value_frac(graph, &m.src)?;
        let n_r = match &m.res {
            Some(r) => Some(spec.try_value_frac(graph, r)?),
            None => None,
        };
        let (qmin, qmax) = scheme::qrange(spec.n_bits, m.relu);
        let unfused = pre_frac.map(|pre| {
            let n_pre = *pre.get(&m.name).unwrap_or(&sp.n_o);
            let (pq_min, pq_max) = scheme::qrange(spec.n_bits, false);
            UnfusedEpi {
                pre_shift: n_x + sp.n_w - n_pre,
                pre_qmin: pq_min,
                pre_qmax: pq_max,
                res_align: n_r.map(|n_r| n_r - n_pre).unwrap_or(0),
                mid_qmin: pq_min * 2,
                mid_qmax: pq_max * 2,
                final_shift: n_pre - sp.n_o,
            }
        });
        Ok(QuantEpi {
            bias_shift: sp.bias_shift(n_x),
            out_shift: sp.out_shift(n_x),
            res_shift: n_r.map(|n_r| sp.res_shift(n_x, n_r)).unwrap_or(0),
            qmin,
            qmax,
            unfused,
        })
    }
}

/// The unfused-ablation epilogue (DESIGN.md §7): quantize immediately
/// after the accumulator, align/add the residual in the *code* domain,
/// requantize again — the dataflow the paper's restructuring removes.
#[derive(Clone, Copy, Debug)]
pub(crate) struct UnfusedEpi {
    /// accumulator → intermediate codes: shift `(N_x + N_w) − N_pre`
    pub pre_shift: i32,
    /// intermediate clamp (signed range)
    pub pre_qmin: i32,
    /// see `pre_qmin`
    pub pre_qmax: i32,
    /// residual codes → intermediate scale: shift `N_r − N_pre`
    pub res_align: i32,
    /// 9-bit intermediate clamp after the residual add
    pub mid_qmin: i32,
    /// see `mid_qmin`
    pub mid_qmax: i32,
    /// intermediate → output codes: shift `N_pre − N_o`
    pub final_shift: i32,
}

/// The kernel variant selected for one GEMM-backed step — resolved at
/// compile time alongside the shapes and shift constants, observable in
/// the plan's `Display` dump (`dfq inspect --plan` / `dfq verify
/// --plan`). The executor never re-derives this on the hot path.
#[derive(Clone, Copy, Debug)]
pub(crate) struct KernelChoice {
    /// run the packed fused-epilogue kernel
    /// ([`crate::tensor::kernels::fused_gemm_into`]) instead of the
    /// reference GEMM + separate `int_epilogue` sweep — selected for
    /// integer plans without the unfused ablation
    pub fused: bool,
    /// skip im2col entirely: a 1×1 stride-1 SAME conv's patch matrix
    /// **is** the input buffer, so the GEMM reads activations in place
    /// (both numeric domains honor this)
    pub elide_im2col: bool,
    /// packed weight storage width the calibrated bit-range licenses
    /// (codes are clamped to `qrange(n_bits, false)` at quantize time;
    /// `dfq verify` re-checks the licensing — `PackWidth` fault)
    pub pack: PackDtype,
}

/// Shared fields of the two GEMM-backed steps (conv, dense).
#[derive(Clone, Copy, Debug)]
pub(crate) struct GemmStep {
    /// index into the plan's parameter table ([`ExecPlan::param_names`])
    pub param: usize,
    /// the K dimension of the GEMM (`kh*kw*cin` for conv, `cin` dense)
    pub kdim: usize,
    /// output channels / features
    pub cout: usize,
    /// fused ReLU (the fp executor applies it; the int executor bakes it
    /// into the clamp range)
    pub relu: bool,
    /// integer epilogue constants — `Some` iff compiled with a spec
    pub q: Option<QuantEpi>,
    /// the compile-time kernel selection for this step
    pub kernel: KernelChoice,
}

/// An im2col convolution step with compile-time geometry.
#[derive(Clone, Copy, Debug)]
pub(crate) struct ConvOp {
    /// kernel height
    pub kh: usize,
    /// kernel width
    pub kw: usize,
    /// input channels
    pub cin: usize,
    /// stride (both dims, SAME padding)
    pub stride: usize,
    /// input spatial height
    pub in_h: usize,
    /// input spatial width
    pub in_w: usize,
    /// output spatial height
    pub ho: usize,
    /// output spatial width
    pub wo: usize,
    /// the GEMM + epilogue
    pub g: GemmStep,
}

/// A dense (fully-connected) step; the source is read as a flat
/// `(N, kdim)` matrix.
#[derive(Clone, Copy, Debug)]
pub(crate) struct DenseOp {
    /// the GEMM + epilogue
    pub g: GemmStep,
}

/// A global-average-pool step (integer-exact rounded shift).
#[derive(Clone, Copy, Debug)]
pub(crate) struct GapOp {
    /// source spatial height
    pub h: usize,
    /// source spatial width
    pub w: usize,
    /// channels
    pub c: usize,
    /// `log2(h*w)` — the exact rounded-shift mean
    pub shift: i32,
    /// integer clamp range — `Some` iff compiled with a spec
    pub clamp: Option<(i32, i32)>,
}

/// What one step computes.
#[derive(Clone, Debug)]
pub(crate) enum Op {
    /// im2col convolution + epilogue
    Conv(ConvOp),
    /// dense GEMM + epilogue
    Dense(DenseOp),
    /// global average pool
    Gap(GapOp),
}

impl Op {
    /// Multiply-accumulates this step performs per image — the
    /// geometry-derived count the audit cost roll-up
    /// ([`crate::analysis::cost`]) charges MAC energy against. Pooling
    /// steps do adds only, which the cost model accounts separately.
    pub(crate) fn macs(&self) -> u64 {
        match self {
            Op::Conv(c) => (c.ho * c.wo * c.g.kdim * c.g.cout) as u64,
            Op::Dense(d) => (d.g.kdim * d.g.cout) as u64,
            Op::Gap(_) => 0,
        }
    }

    /// The shared GEMM fields, when this step is GEMM-backed.
    pub(crate) fn gemm(&self) -> Option<&GemmStep> {
        match self {
            Op::Conv(c) => Some(&c.g),
            Op::Dense(d) => Some(&d.g),
            Op::Gap(_) => None,
        }
    }
}

/// One shape-resolved, slot-addressed instruction of the plan.
#[derive(Clone, Debug)]
pub(crate) struct Step {
    /// module name — debug/dump only, never read on the hot path
    pub name: String,
    /// the operation
    pub op: Op,
    /// input buffer slot
    pub src: usize,
    /// residual buffer slot, if any
    pub res: Option<usize>,
    /// output buffer slot (always distinct from `src`/`res`)
    pub dst: usize,
    /// per-image output shape
    pub out: ValShape,
    /// slots whose values die at this step — recycled after it runs
    pub release: Vec<usize>,
}

/// Quantization bookkeeping of an integer plan.
#[derive(Clone, Copy, Debug)]
pub(crate) struct PlanQuant {
    /// bit-width of every code
    pub n_bits: u32,
    /// fractional bits of the graph input
    pub input_frac: i32,
    /// fractional bits of the final output codes
    pub out_frac: i32,
}

/// A compiled execution plan: the flat, shape-resolved, statically
/// buffered schedule shared by the fp and int engines. Obtained from
/// [`ExecPlan::compile`] (integer) or [`ExecPlan::compile_fp`] (f32);
/// executed by the engines in [`crate::engine`]. `Display` renders the
/// full schedule (`dfq inspect --plan`).
#[derive(Clone, Debug)]
pub struct ExecPlan {
    pub(crate) steps: Vec<Step>,
    /// number of buffer slots a single in-flight executor needs
    pub(crate) slot_count: usize,
    pub(crate) input_slot: usize,
    pub(crate) input_shape: ValShape,
    pub(crate) out_slot: usize,
    pub(crate) out_shape: ValShape,
    /// weighted-module names in parameter-table order
    pub(crate) params: Vec<String>,
    pub(crate) quant: Option<PlanQuant>,
    /// per-step output ranges proved by the static verifier — populated
    /// in debug builds/tests for integer plans (drives the executor's
    /// runtime cross-check), empty otherwise
    pub(crate) ranges: Vec<(i32, i32)>,
    graph_name: String,
}

impl ExecPlan {
    /// Lower a graph into an **integer** plan for the calibrated `spec`:
    /// all name/shape resolution, `Gap` power-of-two validation and
    /// spec-coverage checks happen here, and every shift/clamp constant
    /// is folded in. `input_hwc` is the per-image input resolution the
    /// schedule is resolved for (normally `graph.input_hwc`).
    pub fn compile(
        graph: &Graph,
        spec: &QuantSpec,
        input_hwc: (usize, usize, usize),
    ) -> Result<ExecPlan, DfqError> {
        Self::lower(graph, Some(spec), None, input_hwc)
    }

    /// [`ExecPlan::compile`] with the unfused-ablation epilogue: every
    /// weighted module gains the extra per-layer quantization points at
    /// the `pre_frac` intermediate scales (default: its own `n_o`).
    pub fn compile_unfused(
        graph: &Graph,
        spec: &QuantSpec,
        pre_frac: &HashMap<String, i32>,
        input_hwc: (usize, usize, usize),
    ) -> Result<ExecPlan, DfqError> {
        Self::lower(graph, Some(spec), Some(pre_frac), input_hwc)
    }

    /// Lower the identical schedule without quantization constants — the
    /// floating-point oracle's plan. Shares every structural check with
    /// the integer compile (shape resolution, slot assignment, `Gap`
    /// power-of-two windows).
    pub fn compile_fp(
        graph: &Graph,
        input_hwc: (usize, usize, usize),
    ) -> Result<ExecPlan, DfqError> {
        Self::lower(graph, None, None, input_hwc)
    }

    fn lower(
        graph: &Graph,
        spec: Option<&QuantSpec>,
        pre_frac: Option<&HashMap<String, i32>>,
        input_hwc: (usize, usize, usize),
    ) -> Result<ExecPlan, DfqError> {
        graph.validate()?;
        if graph.modules.is_empty() {
            return Err(DfqError::graph("empty graph: nothing to run"));
        }
        let n_modules = graph.modules.len();
        // value indices: 0 = input, i+1 = output of module i
        let mut value_of: HashMap<&str, usize> = HashMap::new();
        value_of.insert("input", 0);
        for (i, m) in graph.modules.iter().enumerate() {
            value_of.insert(m.name.as_str(), i + 1);
        }
        // liveness: last step that reads each value; a value nobody reads
        // dies right after the step that produces it (the input is always
        // read by module 0 — its src must be "input")
        let mut last_use: Vec<usize> = (0..=n_modules).map(|v| v.saturating_sub(1)).collect();
        for (i, m) in graph.modules.iter().enumerate() {
            last_use[value_of[m.src.as_str()]] = i;
            if let Some(r) = &m.res {
                last_use[value_of[r.as_str()]] = i;
            }
        }
        let out_value = n_modules; // the final module's output

        // slot assignment: greedy reuse over the liveness intervals
        let mut free: Vec<usize> = Vec::new();
        let mut next_slot = 0usize;
        let mut alloc = |free: &mut Vec<usize>| {
            free.pop().unwrap_or_else(|| {
                next_slot += 1;
                next_slot - 1
            })
        };
        let mut slot_of: Vec<usize> = vec![usize::MAX; n_modules + 1];
        slot_of[0] = alloc(&mut free);

        let mut shapes: Vec<ValShape> = vec![ValShape::Spatial {
            h: input_hwc.0,
            w: input_hwc.1,
            c: input_hwc.2,
        }];
        let mut params: Vec<String> = Vec::new();
        let mut steps: Vec<Step> = Vec::with_capacity(n_modules);

        for (i, m) in graph.modules.iter().enumerate() {
            let src_v = value_of[m.src.as_str()];
            let src_shape = shapes[src_v];
            let n_bits = spec.map(|s| s.n_bits).unwrap_or(0);
            // kernel emission: integer plans without the unfused ablation
            // run the packed fused-epilogue kernel; the storage width is
            // licensed by the calibrated bit-range (codes are clamped to
            // qrange(n_bits, false) at quantize time)
            let fused = spec.is_some() && pre_frac.is_none();
            let pack = match spec {
                Some(_) => PackDtype::licensed(n_bits),
                None => PackDtype::I32,
            };
            // integer epilogue constants for a weighted module — the one
            // shared folding of the Eq. 3–4 algebra
            let quant_for = || -> Result<Option<QuantEpi>, DfqError> {
                match spec {
                    Some(spec) => Ok(Some(QuantEpi::resolve(spec, graph, m, pre_frac)?)),
                    None => Ok(None),
                }
            };
            let (op, out) = match &m.kind {
                ModuleKind::Conv { kh, kw, cin, cout, stride } => {
                    let ValShape::Spatial { h, w, c } = src_shape else {
                        return Err(DfqError::graph(format!(
                            "{}: conv expects an NHWC activation with {cin} \
                             channels, but '{}' produces a flat value",
                            m.name, m.src
                        )));
                    };
                    if c != *cin {
                        return Err(DfqError::graph(format!(
                            "{}: conv expects an NHWC activation with {cin} \
                             channels, '{}' has {c}",
                            m.name, m.src
                        )));
                    }
                    let (ho, wo, _, _) =
                        conv_geometry(h, w, *kh, *kw, *stride, Padding::Same);
                    let g = GemmStep {
                        param: params.len(),
                        kdim: kh * kw * cin,
                        cout: *cout,
                        relu: m.relu,
                        q: quant_for()?,
                        kernel: KernelChoice {
                            fused,
                            elide_im2col: *kh == 1 && *kw == 1 && *stride == 1,
                            pack,
                        },
                    };
                    params.push(m.name.clone());
                    (
                        Op::Conv(ConvOp {
                            kh: *kh,
                            kw: *kw,
                            cin: *cin,
                            stride: *stride,
                            in_h: h,
                            in_w: w,
                            ho,
                            wo,
                            g,
                        }),
                        ValShape::Spatial { h: ho, w: wo, c: *cout },
                    )
                }
                ModuleKind::Dense { cin, cout } => {
                    let feats = src_shape.elems();
                    if feats != *cin {
                        return Err(DfqError::graph(format!(
                            "{}: dense weight expects {cin} input features, \
                             activation '{}' provides {feats}",
                            m.name, m.src
                        )));
                    }
                    let g = GemmStep {
                        param: params.len(),
                        kdim: *cin,
                        cout: *cout,
                        relu: m.relu,
                        q: quant_for()?,
                        // dense reads the flat activation directly — there
                        // is no patch matrix to elide
                        kernel: KernelChoice { fused, elide_im2col: false, pack },
                    };
                    params.push(m.name.clone());
                    (Op::Dense(DenseOp { g }), ValShape::Flat { features: *cout })
                }
                ModuleKind::Gap => {
                    let ValShape::Spatial { h, w, c } = src_shape else {
                        return Err(DfqError::graph(format!(
                            "{}: global average pool needs a spatial (NHWC) \
                             source, but '{}' produces a flat value",
                            m.name, m.src
                        )));
                    };
                    let hw = h * w;
                    // the mean is an exact rounded shift ONLY for a
                    // power-of-two window; anything else must be a typed
                    // compile error, not a garbage shift at run time
                    if !hw.is_power_of_two() {
                        return Err(DfqError::graph(format!(
                            "{}: global average pool needs a power-of-two \
                             spatial size for the exact rounded-shift mean, \
                             got {h}x{w}",
                            m.name
                        )));
                    }
                    let clamp = match spec {
                        None => None,
                        Some(spec) => Some(scheme::qrange(
                            n_bits,
                            spec.try_value_unsigned(graph, &m.src)?,
                        )),
                    };
                    (
                        Op::Gap(GapOp {
                            h,
                            w,
                            c,
                            shift: hw.trailing_zeros() as i32,
                            clamp,
                        }),
                        ValShape::Flat { features: c },
                    )
                }
            };
            // residual: full per-image shape equality — an equal element
            // count with a different layout would silently add misaligned
            // channels (the engine contract predating the plan)
            let res_v = match &m.res {
                // the interpreter ignored residuals on Gap modules; the
                // plan preserves that (fusion never emits them)
                Some(_) if matches!(m.kind, ModuleKind::Gap) => None,
                Some(r) => {
                    let rv = value_of[r.as_str()];
                    if shapes[rv] != out {
                        return Err(DfqError::graph(format!(
                            "{}: residual '{r}' shape [{}] does not match \
                             output shape [{}]",
                            m.name, shapes[rv], out
                        )));
                    }
                    Some(rv)
                }
                None => None,
            };
            shapes.push(out);
            // capture input slots while their values are live, THEN
            // allocate dst (so it never aliases a live input), THEN mark
            // dying values for release after the step
            let src_slot = slot_of[src_v];
            let res_slot = res_v.map(|v| slot_of[v]);
            let dst = alloc(&mut free);
            slot_of[i + 1] = dst;
            let mut release: Vec<usize> = Vec::new();
            for v in 0..=i + 1 {
                if last_use[v] == i && v != out_value && slot_of[v] != usize::MAX {
                    let s = slot_of[v];
                    if !release.contains(&s) {
                        release.push(s);
                        free.push(s);
                    }
                    slot_of[v] = usize::MAX; // value is dead
                }
            }
            steps.push(Step {
                name: m.name.clone(),
                op,
                src: src_slot,
                res: res_slot,
                dst,
                out,
                release,
            });
        }
        let out_shape = shapes[out_value];
        let out_slot = slot_of[out_value];
        debug_assert_ne!(out_slot, usize::MAX, "final value is never released");
        let quant = match spec {
            None => None,
            Some(spec) => Some(PlanQuant {
                n_bits: spec.n_bits,
                input_frac: spec.input_frac,
                out_frac: spec.try_value_frac(
                    graph,
                    &graph.modules[n_modules - 1].name,
                )?,
            }),
        };
        #[cfg_attr(not(debug_assertions), allow(unused_mut))]
        let mut plan = ExecPlan {
            steps,
            slot_count: next_slot,
            input_slot: 0,
            input_shape: shapes[0],
            out_slot,
            out_shape,
            params,
            quant,
            ranges: Vec::new(),
            graph_name: graph.name.clone(),
        };
        // debug builds and tests statically verify every compiled plan
        // (interval soundness of the integer algebra + slot safety) and
        // keep the proved per-step ranges for the executor's runtime
        // cross-check; release builds skip it — compile stays cheap and
        // the hot path never pays
        #[cfg(debug_assertions)]
        {
            let report = crate::analysis::verify(&plan);
            if let Some(fault) = report.faults.first() {
                return Err(fault.clone().into());
            }
            if plan.quant.is_some() {
                plan.ranges = report
                    .steps
                    .iter()
                    .map(|s| s.out_range.unwrap_or((i32::MIN, i32::MAX)))
                    .collect();
            }
        }
        Ok(plan)
    }

    /// Validate a batch's shape against the plan's resolved input
    /// resolution — the only shape check left on the run path (shared by
    /// both engines).
    pub fn check_input(&self, shape: &crate::tensor::Shape) -> Result<(), DfqError> {
        let (h, w, c) = self.input_hwc();
        let d = shape.dims();
        if d.len() != 4 || d[1] != h || d[2] != w || d[3] != c {
            return Err(DfqError::invalid(format!(
                "input shape {shape} does not match the compiled plan's \
                 input (N,{h},{w},{c})"
            )));
        }
        Ok(())
    }

    /// Per-image input resolution the plan was compiled for.
    pub fn input_hwc(&self) -> (usize, usize, usize) {
        match self.input_shape {
            ValShape::Spatial { h, w, c } => (h, w, c),
            ValShape::Flat { features } => (1, 1, features),
        }
    }

    /// Number of steps (one per unified module).
    pub fn len(&self) -> usize {
        self.steps.len()
    }

    /// A plan is never empty (compile rejects empty graphs).
    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// Buffer slots one in-flight executor needs — the static memory
    /// assignment (the software analogue of fixed on-chip buffers).
    pub fn slot_count(&self) -> usize {
        self.slot_count
    }

    /// Flattened output features per image.
    pub fn out_elems(&self) -> usize {
        self.out_shape.elems()
    }

    /// Full output dims for a batch of `n`.
    pub(crate) fn out_dims(&self, n: usize) -> Vec<usize> {
        self.out_shape.dims(n)
    }

    /// Weighted-module names in parameter-table order (the binding
    /// contract for executors).
    pub(crate) fn param_names(&self) -> &[String] {
        &self.params
    }
}

impl std::fmt::Display for ExecPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let domain = match &self.quant {
            Some(q) => format!(
                "int{} (input_frac {}, out_frac {})",
                q.n_bits, q.input_frac, q.out_frac
            ),
            None => "f32".to_string(),
        };
        writeln!(
            f,
            "ExecPlan '{}': {} steps, {} buffer slots, {domain}",
            self.graph_name,
            self.steps.len(),
            self.slot_count
        )?;
        writeln!(
            f,
            "  s{} = input [{}]",
            self.input_slot, self.input_shape
        )?;
        for (i, s) in self.steps.iter().enumerate() {
            let (kind, detail) = match &s.op {
                Op::Conv(c) => (
                    "conv",
                    format!("k{}x{}/{} K={}", c.kh, c.kw, c.stride, c.g.kdim),
                ),
                Op::Dense(d) => ("dense", format!("K={}", d.g.kdim)),
                Op::Gap(g) => ("gap", format!(">>{}", g.shift)),
            };
            let relu = match &s.op {
                Op::Conv(ConvOp { g, .. }) | Op::Dense(DenseOp { g }) if g.relu => {
                    " relu"
                }
                _ => "",
            };
            let res = match s.res {
                Some(r) => format!(" +s{r}"),
                None => String::new(),
            };
            let shifts = match &s.op {
                Op::Conv(ConvOp { g, .. }) | Op::Dense(DenseOp { g }) => match g.q {
                    Some(q) => format!(
                        "  shifts(b={} o={} r={})",
                        q.bias_shift, q.out_shift, q.res_shift
                    ),
                    None => String::new(),
                },
                Op::Gap(_) => String::new(),
            };
            let kern = match &s.op {
                Op::Conv(ConvOp { g, .. }) | Op::Dense(DenseOp { g }) => {
                    let variant = if g.kernel.fused {
                        format!("fused/{}", g.kernel.pack)
                    } else {
                        "ref".to_string()
                    };
                    let elide = if g.kernel.elide_im2col { "+elide" } else { "" };
                    format!("  kern[{variant}{elide}]")
                }
                Op::Gap(_) => String::new(),
            };
            let freed = if s.release.is_empty() {
                String::new()
            } else {
                format!(
                    "  free[{}]",
                    s.release
                        .iter()
                        .map(|r| format!("s{r}"))
                        .collect::<Vec<_>>()
                        .join(",")
                )
            };
            writeln!(
                f,
                "  {i:>3} {kind:<5} {:<16} s{}{res} -> s{} [{}]  {detail}{relu}{kern}{shifts}{freed}",
                s.name, s.src, s.dst, s.out
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::UnifiedModule;
    use crate::quant::params::ModuleShifts;

    fn resnet_like() -> Graph {
        Graph {
            name: "t".into(),
            input_hwc: (4, 4, 2),
            modules: vec![
                UnifiedModule {
                    name: "c0".into(),
                    kind: ModuleKind::Conv { kh: 3, kw: 3, cin: 2, cout: 2, stride: 1 },
                    src: "input".into(),
                    res: None,
                    relu: true,
                },
                UnifiedModule {
                    name: "c1".into(),
                    kind: ModuleKind::Conv { kh: 3, kw: 3, cin: 2, cout: 2, stride: 1 },
                    src: "c0".into(),
                    res: Some("c0".into()),
                    relu: true,
                },
                UnifiedModule {
                    name: "gap".into(),
                    kind: ModuleKind::Gap,
                    src: "c1".into(),
                    res: None,
                    relu: false,
                },
                UnifiedModule {
                    name: "fc".into(),
                    kind: ModuleKind::Dense { cin: 2, cout: 3 },
                    src: "gap".into(),
                    res: None,
                    relu: false,
                },
            ],
        }
    }

    fn spec() -> QuantSpec {
        let mut s = QuantSpec::new(8);
        s.input_frac = 5;
        for name in ["c0", "c1", "fc"] {
            s.modules.insert(name.into(), ModuleShifts { n_w: 7, n_b: 7, n_o: 4 });
        }
        s
    }

    #[test]
    fn compiles_and_reuses_slots() {
        let g = resnet_like();
        let plan = ExecPlan::compile(&g, &spec(), g.input_hwc).unwrap();
        assert_eq!(plan.len(), 4);
        // input, c0 (live across c1 as residual), c1, gap, fc — greedy
        // reuse needs at most 3 concurrent buffers here
        assert!(plan.slot_count() <= 3, "slots: {}", plan.slot_count());
        assert_eq!(plan.out_elems(), 3);
        assert_eq!(plan.input_hwc(), (4, 4, 2));
        // a step's dst never aliases its live inputs
        for s in &plan.steps {
            assert_ne!(s.dst, s.src, "{}", s.name);
            if let Some(r) = s.res {
                assert_ne!(s.dst, r, "{}", s.name);
            }
        }
        // the dump names every step
        let dump = plan.to_string();
        for name in ["c0", "c1", "gap", "fc"] {
            assert!(dump.contains(name), "{dump}");
        }
    }

    #[test]
    fn quant_constants_resolved_at_compile() {
        let g = resnet_like();
        let plan = ExecPlan::compile(&g, &spec(), g.input_hwc).unwrap();
        let Op::Conv(c1) = &plan.steps[1].op else { panic!("c1 is conv") };
        let q = c1.g.q.expect("int plan carries quant constants");
        // n_x = n_o(c0) = 4: out shift = 4 + 7 - 4 = 7; res vs c0 same
        assert_eq!(q.out_shift, 7);
        assert_eq!(q.res_shift, 7);
        assert_eq!((q.qmin, q.qmax), (0, 255)); // fused relu -> unsigned
        assert_eq!(plan.quant.unwrap().out_frac, 4);
    }

    #[test]
    fn kernel_selection_resolved_at_compile() {
        // a model with a 1x1 stride-1 conv (elidable), a 1x1 stride-2
        // conv (subsamples -> NOT elidable), and a dense head
        let g = Graph {
            name: "k".into(),
            input_hwc: (4, 4, 2),
            modules: vec![
                UnifiedModule {
                    name: "p0".into(),
                    kind: ModuleKind::Conv { kh: 1, kw: 1, cin: 2, cout: 4, stride: 1 },
                    src: "input".into(),
                    res: None,
                    relu: true,
                },
                UnifiedModule {
                    name: "p1".into(),
                    kind: ModuleKind::Conv { kh: 1, kw: 1, cin: 4, cout: 4, stride: 2 },
                    src: "p0".into(),
                    res: None,
                    relu: true,
                },
                UnifiedModule {
                    name: "fc".into(),
                    kind: ModuleKind::Dense { cin: 2 * 2 * 4, cout: 3 },
                    src: "p1".into(),
                    res: None,
                    relu: false,
                },
            ],
        };
        let mut s = QuantSpec::new(8);
        s.input_frac = 5;
        for name in ["p0", "p1", "fc"] {
            s.modules.insert(name.into(), ModuleShifts { n_w: 7, n_b: 7, n_o: 4 });
        }
        let plan = ExecPlan::compile(&g, &s, g.input_hwc).unwrap();
        let kern = |i: usize| match &plan.steps[i].op {
            Op::Conv(c) => c.g.kernel,
            Op::Dense(d) => d.g.kernel,
            Op::Gap(_) => panic!("gemm step"),
        };
        // 8-bit codes license i8 panels; every step runs fused
        for i in 0..3 {
            assert!(kern(i).fused, "step {i}");
            assert_eq!(kern(i).pack, PackDtype::I8, "step {i}");
        }
        assert!(kern(0).elide_im2col, "1x1 stride-1 elides im2col");
        assert!(!kern(1).elide_im2col, "1x1 stride-2 subsamples");
        assert!(!kern(2).elide_im2col, "dense has no patch matrix");
        // selection is observable in the dump
        let dump = plan.to_string();
        assert!(dump.contains("kern[fused/i8+elide]"), "{dump}");
        assert!(dump.contains("kern[fused/i8]"), "{dump}");
        // a wider bit-range licenses wider storage
        let mut s12 = QuantSpec::new(12);
        s12.input_frac = 5;
        for name in ["p0", "p1", "fc"] {
            s12.modules.insert(name.into(), ModuleShifts { n_w: 7, n_b: 7, n_o: 4 });
        }
        let plan12 = ExecPlan::compile(&g, &s12, g.input_hwc).unwrap();
        let Op::Conv(c) = &plan12.steps[0].op else { panic!("conv") };
        assert_eq!(c.g.kernel.pack, PackDtype::I16);
        // the unfused ablation and the fp oracle stay on the reference
        // kernels (the ablation's extra quant points cannot fuse)
        let pre: HashMap<String, i32> = HashMap::new();
        let plan_u = ExecPlan::compile_unfused(&g, &s, &pre, g.input_hwc).unwrap();
        let Op::Conv(c) = &plan_u.steps[0].op else { panic!("conv") };
        assert!(!c.g.kernel.fused);
        let plan_fp = ExecPlan::compile_fp(&g, g.input_hwc).unwrap();
        let Op::Conv(c) = &plan_fp.steps[0].op else { panic!("conv") };
        assert!(!c.g.kernel.fused);
        assert!(c.g.kernel.elide_im2col, "fp plans elide 1x1 im2col too");
        assert!(plan_fp.to_string().contains("kern[ref+elide]"));
    }

    #[test]
    fn uncovered_module_fails_at_compile() {
        let g = resnet_like();
        let mut s = spec();
        s.modules.remove("c1");
        let err = ExecPlan::compile(&g, &s, g.input_hwc).unwrap_err();
        assert!(matches!(err, DfqError::Graph(_)), "{err}");
        assert!(err.to_string().contains("c1"), "{err}");
    }

    #[test]
    fn non_pow2_gap_fails_at_compile() {
        let mut g = resnet_like();
        g.input_hwc = (3, 4, 2);
        let err = ExecPlan::compile_fp(&g, g.input_hwc).unwrap_err();
        assert!(err.to_string().contains("power-of-two"), "{err}");
    }

    #[test]
    fn dangling_res_fails_at_compile() {
        let mut g = resnet_like();
        g.modules[1].res = Some("nope".into());
        let err = ExecPlan::compile_fp(&g, g.input_hwc).unwrap_err();
        assert!(err.to_string().contains("nope"), "{err}");
    }

    #[test]
    fn conv_over_flat_value_fails_at_compile() {
        let mut g = resnet_like();
        // a conv reading the gap output (flat) is a shape error
        g.modules.push(UnifiedModule {
            name: "bad".into(),
            kind: ModuleKind::Conv { kh: 1, kw: 1, cin: 2, cout: 2, stride: 1 },
            src: "gap".into(),
            res: None,
            relu: false,
        });
        let err = ExecPlan::compile_fp(&g, g.input_hwc).unwrap_err();
        assert!(err.to_string().contains("NHWC"), "{err}");
    }

    #[test]
    fn dense_fan_in_mismatch_fails_at_compile() {
        let mut g = resnet_like();
        g.modules[3].kind = ModuleKind::Dense { cin: 5, cout: 3 };
        let err = ExecPlan::compile_fp(&g, g.input_hwc).unwrap_err();
        assert!(err.to_string().contains("features"), "{err}");
    }

    #[test]
    fn residual_shape_mismatch_fails_at_compile() {
        let mut g = resnet_like();
        // stride-2 conv with a full-resolution residual
        g.modules[1].kind = ModuleKind::Conv { kh: 3, kw: 3, cin: 2, cout: 2, stride: 2 };
        // drop gap+fc so the only error is the residual mismatch
        g.modules.truncate(2);
        let err = ExecPlan::compile_fp(&g, g.input_hwc).unwrap_err();
        assert!(err.to_string().contains("does not match"), "{err}");
    }

    #[test]
    fn empty_graph_fails_at_compile() {
        let g = Graph { name: "e".into(), input_hwc: (2, 2, 1), modules: vec![] };
        assert!(ExecPlan::compile_fp(&g, g.input_hwc).is_err());
    }
}
