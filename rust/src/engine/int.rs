//! The integer-only inference engine — the paper's custom hardware unit
//! in software (Eq. 3–4):
//!
//! * weights/biases/activations are n-bit integer codes (held in i32
//!   lanes), accumulation is 32-bit;
//! * biases are aligned into the accumulator domain by a left shift of
//!   `(N_x + N_w) − N_b` (Eq. 3), residuals by `(N_x + N_w) − N_r`;
//! * the output is requantized with a rounded right shift of
//!   `(N_x + N_w) − N_o` and clamped — to the unsigned range after a
//!   fused ReLU (Fig. 1 b/c), the signed range otherwise;
//! * global-average-pool divides by a power-of-two spatial size with the
//!   same rounded shift, so the whole network is exact integer math.
//!
//! Bit-exact with `python/compile/kernels/ref.py` (and therefore with the
//! Pallas kernels and the AOT artifacts) — integration tests chain all
//! three.
//!
//! ## Execution model
//!
//! The batch entry points ([`IntEngine::run`], [`IntEngine::run_scratch`],
//! [`IntEngine::run_codes_scratch`]) lower the graph into a flat
//! [`ExecPlan`] — shape-resolved steps over statically assigned buffer
//! slots — and execute it through the shared executor in
//! [`crate::engine::exec`]. All name/shape resolution, spec-coverage
//! checks and `Gap` power-of-two validation happen in
//! [`ExecPlan::compile`]; the executor touches only slot indices and
//! resolved constants. Long-lived callers compile once
//! ([`IntEngine::plan`]) and reuse the plan via
//! [`IntEngine::run_plan_scratch`] — the serving deploy engine does
//! exactly that, with one [`Scratch`] arena per in-flight shard, so a
//! warm engine performs zero large allocations per batch (the software
//! analogue of the paper's fixed on-chip buffers).
//!
//! [`IntEngine::run_module`] keeps the dynamic per-module path the joint
//! calibrator needs (it probes prefixes of a partially calibrated
//! graph); it shares the epilogue kernels with the plan executor, so the
//! two paths are bit-identical by construction
//! (`rust/tests/prop_plan.rs` asserts it over random graphs).
//!
//! Malformed inputs (a spec that doesn't cover a module, a dangling
//! `src`/`res` name, a non-power-of-two pooling window, a residual shape
//! mismatch) surface as [`DfqError`] — never a silent wrong answer, in
//! release builds included.
//!
//! The engine also supports the **unfused** ablation (DESIGN.md §7):
//! quantization immediately after the conv accumulator and again after
//! the residual add — the strategy the paper's Fig.-1 restructuring
//! removes. It needs extra calibrated scales (`pre_frac`), supplied by
//! the ablation calibrator.

use std::collections::HashMap;

use crate::engine::exec;
use crate::engine::plan::{ExecPlan, GapOp, QuantEpi};
use crate::error::DfqError;
use crate::graph::bn_fold::FoldedParams;
use crate::graph::{Graph, ModuleKind};
use crate::quant::params::QuantSpec;
use crate::quant::scheme;
use crate::tensor::im2col::Padding;
use crate::tensor::{ops_int, Shape, Tensor, TensorI32};

pub use crate::engine::exec::Scratch;

/// Quantized parameters of one module, ready for the integer engine.
#[derive(Clone, Debug)]
pub struct QuantizedParams {
    /// weight codes (HWIO conv / (Cin,Cout) dense)
    pub w: TensorI32,
    /// bias codes
    pub b: Vec<i32>,
}

/// Quantize all folded parameters per a spec (shared by the engine and
/// the PJRT path, so both feed identical codes).
pub fn quantize_params(
    graph: &Graph,
    folded: &HashMap<String, FoldedParams>,
    spec: &QuantSpec,
) -> HashMap<String, QuantizedParams> {
    let mut out = HashMap::new();
    for m in graph.weight_modules() {
        // during joint calibration only a prefix of the graph is
        // calibrated; quantize what the spec covers
        let Some(&s) = spec.modules.get(&m.name) else { continue };
        let p = &folded[&m.name];
        let w = scheme::quantize_tensor(&p.w, s.n_w, spec.n_bits, false);
        let b: Vec<i32> = p
            .b
            .iter()
            .map(|&x| scheme::quantize_val(x, s.n_b, spec.n_bits, false))
            .collect();
        out.insert(m.name.clone(), QuantizedParams { w, b });
    }
    out
}

/// The integer-only executor.
pub struct IntEngine<'g> {
    graph: &'g Graph,
    spec: &'g QuantSpec,
    qparams: std::borrow::Cow<'g, HashMap<String, QuantizedParams>>,
    /// row-block GEMM parallelism (1 = serial)
    threads: usize,
    /// unfused ablation: per-module fractional bits of the intermediate
    /// (pre-ReLU / pre-add) quantization points
    pub pre_frac: Option<HashMap<String, i32>>,
}

impl<'g> IntEngine<'g> {
    /// Build: quantizes the folded weights once.
    pub fn new(
        graph: &'g Graph,
        folded: &HashMap<String, FoldedParams>,
        spec: &'g QuantSpec,
    ) -> Self {
        let qparams = std::borrow::Cow::Owned(quantize_params(graph, folded, spec));
        IntEngine { graph, spec, qparams, threads: 1, pre_frac: None }
    }

    /// Build over parameters already quantized by [`quantize_params`] —
    /// lets long-lived callers (the serving engines) pay the weight
    /// quantization once instead of per batch.
    pub fn with_qparams(
        graph: &'g Graph,
        spec: &'g QuantSpec,
        qparams: &'g HashMap<String, QuantizedParams>,
    ) -> Self {
        IntEngine {
            graph,
            spec,
            qparams: std::borrow::Cow::Borrowed(qparams),
            threads: 1,
            pre_frac: None,
        }
    }

    /// Split each GEMM over `threads` row-blocks (bit-exact — output
    /// rows are independent). Useful when the batch is too small for the
    /// deploy layer to shard along N.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Access the quantized parameters (the PJRT path feeds these to the
    /// q_logits artifact).
    pub fn qparams(&self) -> &HashMap<String, QuantizedParams> {
        &self.qparams
    }

    /// Compile the graph into the flat [`ExecPlan`] this engine executes
    /// (honouring the current `pre_frac` ablation setting). All
    /// graph/spec validation errors surface here; batch entry points
    /// compile per call, so long-lived callers should cache the plan and
    /// use [`IntEngine::run_plan_scratch`].
    pub fn plan(&self) -> Result<ExecPlan, DfqError> {
        match &self.pre_frac {
            Some(pre) => ExecPlan::compile_unfused(
                self.graph,
                self.spec,
                pre,
                self.graph.input_hwc,
            ),
            None => ExecPlan::compile(self.graph, self.spec, self.graph.input_hwc),
        }
    }

    /// Quantize a normalised f32 input batch into codes.
    pub fn quantize_input(&self, x: &Tensor) -> TensorI32 {
        scheme::quantize_tensor(x, self.spec.input_frac, self.spec.n_bits, false)
    }

    /// Run on input codes, returning every module's codes (no liveness —
    /// calibration and the cross-language tests read intermediates).
    pub fn run_acts(
        &self,
        x_int: &TensorI32,
    ) -> Result<HashMap<String, TensorI32>, DfqError> {
        let mut acts: HashMap<String, TensorI32> = HashMap::new();
        acts.insert("input".to_string(), x_int.clone());
        for m in &self.graph.modules {
            let out = self.run_module(m, &acts)?;
            acts.insert(m.name.clone(), out);
        }
        Ok(acts)
    }

    /// Execute one module given the activations so far — the dynamic
    /// per-module path the joint calibrator uses to probe prefixes of a
    /// partially calibrated graph. Shares its kernels with the plan
    /// executor, so it is bit-identical to [`IntEngine::run`].
    pub fn run_module(
        &self,
        m: &crate::graph::UnifiedModule,
        acts: &HashMap<String, TensorI32>,
    ) -> Result<TensorI32, DfqError> {
        let mut scratch = Scratch::new();
        self.run_module_scratch(m, acts, &mut scratch)
    }

    /// [`IntEngine::run_module`] through a reusable [`Scratch`] arena.
    pub fn run_module_scratch(
        &self,
        m: &crate::graph::UnifiedModule,
        acts: &HashMap<String, TensorI32>,
        scratch: &mut Scratch,
    ) -> Result<TensorI32, DfqError> {
        let src = acts.get(&m.src).ok_or_else(|| {
            DfqError::graph(format!("{}: missing input activation '{}'", m.name, m.src))
        })?;
        let n_bits = self.spec.n_bits;
        match &m.kind {
            ModuleKind::Gap => {
                if src.shape.rank() != 4 {
                    return Err(DfqError::graph(format!(
                        "{}: global average pool needs an NHWC activation, \
                         '{}' has rank {}",
                        m.name,
                        m.src,
                        src.shape.rank()
                    )));
                }
                let (n, h, w, c) = (
                    src.shape.dim(0),
                    src.shape.dim(1),
                    src.shape.dim(2),
                    src.shape.dim(3),
                );
                let hw = h * w;
                // the mean is an exact rounded shift ONLY for a
                // power-of-two window; anything else must be a typed
                // error, not a garbage shift from trailing_zeros
                if !hw.is_power_of_two() {
                    return Err(DfqError::graph(format!(
                        "{}: global average pool needs a power-of-two spatial \
                         size for the exact rounded-shift mean, got {h}x{w}",
                        m.name
                    )));
                }
                let unsigned = self.spec.try_value_unsigned(self.graph, &m.src)?;
                let clamp = scheme::qrange(n_bits, unsigned);
                let g = GapOp {
                    h,
                    w,
                    c,
                    shift: hw.trailing_zeros() as i32,
                    clamp: Some(clamp),
                };
                let mut out = scratch.take(n * c); // pre-zeroed: gap sums in place
                exec::int_gap(&g, clamp, n, &src.data, &mut out);
                Ok(TensorI32 { shape: Shape(vec![n, c]), data: out })
            }
            ModuleKind::Conv { .. } | ModuleKind::Dense { .. } => {
                // coverage first (error-precedence: an uncovered module
                // reports as such, not as missing quantized parameters)
                self.spec.try_module(&m.name)?;
                let qp = self.qparams.get(&m.name).ok_or_else(|| {
                    DfqError::graph(format!(
                        "module '{}' has no quantized parameters",
                        m.name
                    ))
                })?;
                let (mut acc, cout) = match &m.kind {
                    ModuleKind::Conv { kh, kw, cin, cout, stride } => {
                        if src.shape.rank() != 4 || src.shape.dim(3) != *cin {
                            return Err(DfqError::graph(format!(
                                "{}: conv expects an NHWC activation with \
                                 {cin} channels, '{}' has shape {}",
                                m.name, m.src, src.shape
                            )));
                        }
                        // exact-size take: the GEMM overwrites every
                        // element, so the stale reused prefix never leaks
                        let (ho, wo, _, _) = crate::tensor::im2col::conv_geometry(
                            src.shape.dim(1),
                            src.shape.dim(2),
                            *kh,
                            *kw,
                            *stride,
                            Padding::Same,
                        );
                        let mut out =
                            scratch.take_uninit(src.shape.dim(0) * ho * wo * *cout);
                        let shape = ops_int::conv2d_acc_into(
                            src,
                            &qp.w,
                            *stride,
                            Padding::Same,
                            &mut scratch.patches,
                            &mut out,
                            self.threads,
                        );
                        (TensorI32 { shape, data: out }, *cout)
                    }
                    ModuleKind::Dense { .. } => {
                        let rows = src.shape.dim(0);
                        let cin = if rows == 0 { 0 } else { src.numel() / rows };
                        if qp.w.shape.dim(0) != cin {
                            return Err(DfqError::graph(format!(
                                "{}: dense weight expects {} input features, \
                                 activation provides {cin}",
                                m.name,
                                qp.w.shape.dim(0)
                            )));
                        }
                        let cout = qp.w.shape.dim(1);
                        let mut out = scratch.take_uninit(rows * cout);
                        ops_int::gemm_i32_into(
                            &src.data,
                            &qp.w.data,
                            rows,
                            cin,
                            cout,
                            &mut out,
                            self.threads,
                        );
                        (TensorI32 { shape: Shape(vec![rows, cout]), data: out }, cout)
                    }
                    ModuleKind::Gap => {
                        return Err(DfqError::graph(format!(
                            "{}: pooling module reached the weighted-module path",
                            m.name
                        )))
                    }
                };
                // resolve the residual (name + full shape equality: an
                // equal element count with a different layout would
                // silently add misaligned channels)
                let res = match &m.res {
                    Some(r) => {
                        let rt = acts.get(r).ok_or_else(|| {
                            DfqError::graph(format!(
                                "{}: missing residual activation '{r}'",
                                m.name
                            ))
                        })?;
                        if rt.shape != acc.shape {
                            return Err(DfqError::graph(format!(
                                "{}: residual '{r}' shape {} does not match \
                                 output shape {}",
                                m.name, rt.shape, acc.shape
                            )));
                        }
                        Some(rt)
                    }
                    None => None,
                };
                // the ONE shared folding of the Eq. 3–4 epilogue
                // constants (the plan compiler calls the same resolver)
                let q = QuantEpi::resolve(
                    self.spec,
                    self.graph,
                    m,
                    self.pre_frac.as_ref(),
                )?;
                let aligned: Vec<i32> = qp
                    .b
                    .iter()
                    .map(|&b| scheme::align(b, q.bias_shift))
                    .collect();
                exec::int_epilogue(
                    &q,
                    cout,
                    &aligned,
                    res.map(|rt| rt.data.as_slice()),
                    &mut acc.data,
                );
                Ok(acc)
            }
        }
    }

    /// Full pipeline from a normalised f32 batch to final output codes
    /// through the compiled plan (dead activations recycle as their last
    /// consumer retires).
    pub fn run(&self, x: &Tensor) -> Result<TensorI32, DfqError> {
        let mut scratch = Scratch::new();
        self.run_scratch(x, &mut scratch)
    }

    /// [`IntEngine::run`] through a caller-owned [`Scratch`]: the input
    /// is quantized into a recycled buffer and dead activations return
    /// to the arena, so a warm scratch makes steady-state serving
    /// allocation-free for the large buffers. Compiles the plan per
    /// call; cache it with [`IntEngine::plan`] +
    /// [`IntEngine::run_plan_scratch`] on hot paths.
    pub fn run_scratch(
        &self,
        x: &Tensor,
        scratch: &mut Scratch,
    ) -> Result<TensorI32, DfqError> {
        let plan = self.plan()?;
        self.run_plan_scratch(&plan, x, scratch)
    }

    /// Execute a plan previously compiled by [`IntEngine::plan`] — the
    /// compile-once hot path (no name or shape resolution per batch).
    pub fn run_plan_scratch(
        &self,
        plan: &ExecPlan,
        x: &Tensor,
        scratch: &mut Scratch,
    ) -> Result<TensorI32, DfqError> {
        plan.check_input(&x.shape)?;
        let mut codes = scratch.take_uninit(x.numel());
        for (dst, &v) in codes.iter_mut().zip(&x.data) {
            *dst = scheme::quantize_val(v, self.spec.input_frac, self.spec.n_bits, false);
        }
        self.execute_codes(plan, codes, x.shape.dim(0), scratch)
    }

    /// [`IntEngine::run_scratch`] from already-quantized input codes —
    /// the input tensor is consumed so its buffer joins the recycling
    /// pool once dead (callers can feed a buffer taken from the same
    /// scratch and close the loop entirely).
    pub fn run_codes_scratch(
        &self,
        x_int: TensorI32,
        scratch: &mut Scratch,
    ) -> Result<TensorI32, DfqError> {
        let plan = self.plan()?;
        plan.check_input(&x_int.shape)?;
        let n = x_int.shape.dim(0);
        self.execute_codes(&plan, x_int.data, n, scratch)
    }

    fn execute_codes(
        &self,
        plan: &ExecPlan,
        codes: Vec<i32>,
        n: usize,
        scratch: &mut Scratch,
    ) -> Result<TensorI32, DfqError> {
        let biases = exec::aligned_biases(plan, &self.qparams)?;
        // bind-time kernel emission: panels repack per call here (this
        // path already binds biases per call); the deploy engine packs
        // once and reuses across every batch
        let packed = exec::pack_plan(plan, &self.qparams)?;
        let views = exec::int_views(plan, &self.qparams, &biases, &packed);
        let out = exec::execute(
            plan,
            &exec::IntDomain { params: &views },
            codes,
            n,
            scratch,
            self.threads,
        )?;
        Ok(TensorI32 { shape: Shape(plan.out_dims(n)), data: out })
    }

    /// Final logits dequantized to f32 (for metrics that need scores).
    pub fn run_dequant(&self, x: &Tensor) -> Result<Tensor, DfqError> {
        let last = &self
            .graph
            .modules
            .last()
            .ok_or_else(|| DfqError::graph("empty graph: nothing to run"))?
            .name;
        let out = self.run(x)?;
        Ok(scheme::dequantize_tensor(
            &out,
            self.spec.try_value_frac(self.graph, last)?,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::UnifiedModule;
    use crate::quant::params::ModuleShifts;

    /// Hand-checkable single conv: x scale 2^-4, w scale 2^-6, bias 2^-5,
    /// out 2^-3.
    #[test]
    fn single_conv_matches_hand_math() {
        let graph = Graph {
            name: "t".into(),
            input_hwc: (1, 1, 1),
            modules: vec![UnifiedModule {
                name: "c".into(),
                kind: ModuleKind::Conv { kh: 1, kw: 1, cin: 1, cout: 1, stride: 1 },
                src: "input".into(),
                res: None,
                relu: false,
            }],
        };
        let mut folded = HashMap::new();
        folded.insert(
            "c".to_string(),
            FoldedParams { w: Tensor::from_vec(&[1, 1, 1, 1], vec![0.75]), b: vec![0.5] },
        );
        let mut spec = QuantSpec::new(8);
        spec.input_frac = 4;
        spec.modules.insert("c".into(), ModuleShifts { n_w: 6, n_b: 5, n_o: 3 });
        let eng = IntEngine::new(&graph, &folded, &spec);
        // x = 1.25 -> code 20; w = 0.75 -> code 48; b = 0.5 -> code 16
        let x = Tensor::from_vec(&[1, 1, 1, 1], vec![1.25]);
        let out = eng.run(&x).unwrap();
        // acc = 20*48 + (16 << (4+6-5)) = 960 + 512 = 1472 at scale 2^-10
        // out = round(1472 / 2^(10-3)) = round(11.5) = 12 -> 1.5 at 2^-3
        assert_eq!(out.data[0], 12);
        let deq = eng.run_dequant(&x).unwrap();
        assert!((deq.data[0] - 1.5).abs() < 1e-6);
    }

    /// The engine must agree with a float-side simulation of Q for a
    /// random fused residual module.
    #[test]
    fn residual_module_exactness_vs_scheme_sim() {
        let graph = Graph {
            name: "t".into(),
            input_hwc: (4, 4, 2),
            modules: vec![
                UnifiedModule {
                    name: "c0".into(),
                    kind: ModuleKind::Conv { kh: 1, kw: 1, cin: 2, cout: 2, stride: 1 },
                    src: "input".into(),
                    res: None,
                    relu: true,
                },
                UnifiedModule {
                    name: "c1".into(),
                    kind: ModuleKind::Conv { kh: 3, kw: 3, cin: 2, cout: 2, stride: 1 },
                    src: "c0".into(),
                    res: Some("c0".into()),
                    relu: true,
                },
            ],
        };
        let mut rng = crate::util::rng::Pcg::new(11);
        let mut folded = HashMap::new();
        for name in ["c0", "c1"] {
            let k = if name == "c0" { 1 } else { 3 };
            let w = Tensor::from_vec(
                &[k, k, 2, 2],
                (0..k * k * 4).map(|_| rng.normal_ms(0.0, 0.4)).collect(),
            );
            folded.insert(
                name.to_string(),
                FoldedParams { w, b: vec![rng.normal_ms(0.0, 0.2), rng.normal_ms(0.0, 0.2)] },
            );
        }
        let mut spec = QuantSpec::new(8);
        spec.input_frac = 5;
        spec.modules.insert("c0".into(), ModuleShifts { n_w: 7, n_b: 7, n_o: 5 });
        spec.modules.insert("c1".into(), ModuleShifts { n_w: 7, n_b: 7, n_o: 4 });
        let eng = IntEngine::new(&graph, &folded, &spec);
        let x = Tensor::from_vec(&[1, 4, 4, 2], (0..32).map(|_| rng.normal()).collect());
        let acts = eng.run_acts(&eng.quantize_input(&x)).unwrap();
        // every activation is inside its clamp range
        for name in ["c0", "c1"] {
            let (qmin, qmax) = scheme::qrange(8, true);
            for &v in &acts[name].data {
                assert!(v >= qmin && v <= qmax);
            }
        }
        // and c1's codes dequantize close to the FP engine's output
        let fpe = crate::engine::fp::FpEngine::new(&graph, &folded);
        let facts = fpe.run_acts(&x).unwrap();
        let deq = scheme::dequantize_tensor(&acts["c1"], 4);
        let mse = crate::util::mathutil::mse(&deq.data, &facts["c1"].data);
        assert!(mse < 0.01, "integer path diverged: mse={mse}");
    }

    #[test]
    fn unfused_mode_runs_and_differs() {
        // same graph as above; the ablation engine should produce valid
        // codes that (generally) differ from the fused ones.
        let graph = Graph {
            name: "t".into(),
            input_hwc: (4, 4, 2),
            modules: vec![
                UnifiedModule {
                    name: "c0".into(),
                    kind: ModuleKind::Conv { kh: 3, kw: 3, cin: 2, cout: 2, stride: 1 },
                    src: "input".into(),
                    res: None,
                    relu: true,
                },
            ],
        };
        let mut rng = crate::util::rng::Pcg::new(13);
        let mut folded = HashMap::new();
        folded.insert(
            "c0".to_string(),
            FoldedParams {
                w: Tensor::from_vec(&[3, 3, 2, 2], (0..36).map(|_| rng.normal_ms(0.0, 0.4)).collect()),
                b: vec![0.1, -0.1],
            },
        );
        let mut spec = QuantSpec::new(8);
        spec.input_frac = 5;
        spec.modules.insert("c0".into(), ModuleShifts { n_w: 7, n_b: 7, n_o: 5 });
        let mut eng = IntEngine::new(&graph, &folded, &spec);
        let x = Tensor::from_vec(&[1, 4, 4, 2], (0..32).map(|_| rng.normal()).collect());
        let fused = eng.run(&x).unwrap();
        let mut pre = HashMap::new();
        pre.insert("c0".to_string(), 3); // coarse intermediate scale
        eng.pre_frac = Some(pre);
        let unfused = eng.run(&x).unwrap();
        assert_eq!(fused.shape, unfused.shape);
        // coarse pre-quantization loses information vs the fused path
        assert_ne!(fused.data, unfused.data);
    }

    /// Residual graph for the liveness / error-path tests.
    fn resnet_like() -> (Graph, HashMap<String, FoldedParams>, QuantSpec) {
        let graph = Graph {
            name: "t".into(),
            input_hwc: (4, 4, 2),
            modules: vec![
                UnifiedModule {
                    name: "c0".into(),
                    kind: ModuleKind::Conv { kh: 3, kw: 3, cin: 2, cout: 2, stride: 1 },
                    src: "input".into(),
                    res: None,
                    relu: true,
                },
                UnifiedModule {
                    name: "c1".into(),
                    kind: ModuleKind::Conv { kh: 3, kw: 3, cin: 2, cout: 2, stride: 1 },
                    src: "c0".into(),
                    res: Some("c0".into()),
                    relu: true,
                },
                UnifiedModule {
                    name: "gap".into(),
                    kind: ModuleKind::Gap,
                    src: "c1".into(),
                    res: None,
                    relu: false,
                },
                UnifiedModule {
                    name: "fc".into(),
                    kind: ModuleKind::Dense { cin: 2, cout: 3 },
                    src: "gap".into(),
                    res: None,
                    relu: false,
                },
            ],
        };
        let mut rng = crate::util::rng::Pcg::new(17);
        let mut folded = HashMap::new();
        for m in graph.weight_modules() {
            let (shape, cout): (Vec<usize>, usize) = match &m.kind {
                ModuleKind::Conv { kh, kw, cin, cout, .. } => {
                    (vec![*kh, *kw, *cin, *cout], *cout)
                }
                ModuleKind::Dense { cin, cout } => (vec![*cin, *cout], *cout),
                ModuleKind::Gap => unreachable!(),
            };
            let n: usize = shape.iter().product();
            folded.insert(
                m.name.clone(),
                FoldedParams {
                    w: Tensor::from_vec(
                        &shape,
                        (0..n).map(|_| rng.normal_ms(0.0, 0.3)).collect(),
                    ),
                    b: (0..cout).map(|_| rng.normal_ms(0.0, 0.1)).collect(),
                },
            );
        }
        let mut spec = QuantSpec::new(8);
        spec.input_frac = 5;
        for name in ["c0", "c1", "fc"] {
            spec.modules.insert(name.into(), ModuleShifts { n_w: 7, n_b: 7, n_o: 4 });
        }
        (graph, folded, spec)
    }

    #[test]
    fn liveness_run_matches_retain_everything_run_acts() {
        let (graph, folded, spec) = resnet_like();
        let eng = IntEngine::new(&graph, &folded, &spec);
        let mut rng = crate::util::rng::Pcg::new(18);
        let x = Tensor::from_vec(&[2, 4, 4, 2], (0..64).map(|_| rng.normal()).collect());
        let mut acts = eng.run_acts(&eng.quantize_input(&x)).unwrap();
        let want = acts.remove("fc").unwrap();
        let got = eng.run(&x).unwrap();
        assert_eq!(want, got);
        // a warm scratch over repeated runs stays bit-stable, through a
        // cached plan too
        let plan = eng.plan().unwrap();
        let mut scratch = Scratch::new();
        for _ in 0..3 {
            assert_eq!(eng.run_scratch(&x, &mut scratch).unwrap(), want);
            assert_eq!(eng.run_plan_scratch(&plan, &x, &mut scratch).unwrap(), want);
        }
    }

    #[test]
    fn gemm_threads_are_bit_exact_through_the_engine() {
        let (graph, folded, spec) = resnet_like();
        let mut rng = crate::util::rng::Pcg::new(19);
        // batch 8 -> 128 conv output rows, enough for real row-blocking
        let x = Tensor::from_vec(&[8, 4, 4, 2], (0..256).map(|_| rng.normal()).collect());
        let want = IntEngine::new(&graph, &folded, &spec).run(&x).unwrap();
        for threads in [2usize, 4] {
            let eng = IntEngine::new(&graph, &folded, &spec).with_threads(threads);
            assert_eq!(eng.run(&x).unwrap(), want, "threads={threads}");
        }
    }

    #[test]
    fn non_power_of_two_gap_is_typed_error_not_garbage() {
        // regression: this was a debug_assert!, so release builds computed
        // a garbage shift from trailing_zeros(12) and served wrong answers
        let graph = Graph {
            name: "t".into(),
            input_hwc: (3, 4, 2),
            modules: vec![UnifiedModule {
                name: "gap".into(),
                kind: ModuleKind::Gap,
                src: "input".into(),
                res: None,
                relu: false,
            }],
        };
        let folded = HashMap::new();
        let spec = QuantSpec::new(8);
        let eng = IntEngine::new(&graph, &folded, &spec);
        let x = Tensor::zeros(&[1, 3, 4, 2]);
        let err = eng.run(&x).unwrap_err();
        assert!(matches!(err, DfqError::Graph(_)), "{err}");
        assert!(err.to_string().contains("power-of-two"), "{err}");
    }

    #[test]
    fn conv_over_non_spatial_activation_is_typed_error() {
        // conv fed a rank-2 activation (e.g. a dense output) must be a
        // typed error, not an index panic inside im2col
        let (graph, folded, spec) = resnet_like();
        let eng = IntEngine::new(&graph, &folded, &spec);
        let mut acts = HashMap::new();
        // "gap" is a graph value (so scale lookup succeeds) whose
        // activation is legitimately rank 2
        acts.insert("gap".to_string(), TensorI32::zeros(&[1, 2]));
        let mut m = graph.modules[1].clone(); // conv c1
        m.src = "gap".into();
        m.res = None;
        let err = eng.run_module(&m, &acts).unwrap_err();
        assert!(matches!(err, DfqError::Graph(_)), "{err}");
        assert!(err.to_string().contains("NHWC"), "{err}");
    }

    #[test]
    fn gap_over_non_spatial_activation_is_typed_error() {
        // gap after dense: the activation is rank 2, so there is no
        // pooling window — must be a typed error, not an index panic
        let (graph, folded, spec) = resnet_like();
        let eng = IntEngine::new(&graph, &folded, &spec);
        let mut acts = HashMap::new();
        acts.insert("flat".to_string(), TensorI32::zeros(&[1, 4]));
        let mut m = graph.modules[2].clone(); // the gap module
        m.src = "flat".into();
        let err = eng.run_module(&m, &acts).unwrap_err();
        assert!(matches!(err, DfqError::Graph(_)), "{err}");
        assert!(err.to_string().contains("rank"), "{err}");
    }

    #[test]
    fn uncovered_module_is_typed_error_not_panic() {
        // regression: quantize_params deliberately skips modules the spec
        // doesn't cover, and run_module used to panic on the map lookup;
        // with the plan the error now surfaces at compile()
        let (graph, folded, mut spec) = resnet_like();
        spec.modules.remove("c1");
        let eng = IntEngine::new(&graph, &folded, &spec);
        let err = eng.plan().unwrap_err();
        assert!(matches!(err, DfqError::Graph(_)), "{err}");
        assert!(err.to_string().contains("c1"), "{err}");
        // ...and run() surfaces the same compile error
        let x = Tensor::zeros(&[1, 4, 4, 2]);
        let err = eng.run(&x).unwrap_err();
        assert!(err.to_string().contains("c1"), "{err}");
    }

    #[test]
    fn dangling_names_are_typed_errors_not_panics() {
        let (graph, folded, spec) = resnet_like();
        let eng = IntEngine::new(&graph, &folded, &spec);
        // missing src
        let acts: HashMap<String, TensorI32> = HashMap::new();
        let err = eng.run_module(&graph.modules[0], &acts).unwrap_err();
        assert!(matches!(err, DfqError::Graph(_)), "{err}");
        // missing residual
        let mut acts = HashMap::new();
        acts.insert("c0".to_string(), TensorI32::zeros(&[1, 4, 4, 2]));
        let mut m = graph.modules[1].clone();
        m.res = Some("nope".into());
        let err = eng.run_module(&m, &acts).unwrap_err();
        assert!(err.to_string().contains("nope"), "{err}");
    }

    #[test]
    fn residual_shape_mismatch_is_typed_error() {
        let (graph, folded, spec) = resnet_like();
        let eng = IntEngine::new(&graph, &folded, &spec);
        let mut acts = HashMap::new();
        acts.insert("c0".to_string(), TensorI32::zeros(&[1, 4, 4, 2]));
        // residual with the wrong element count
        acts.insert("bad".to_string(), TensorI32::zeros(&[1, 2, 2, 2]));
        let mut m = graph.modules[1].clone();
        m.res = Some("bad".into());
        let err = eng.run_module(&m, &acts).unwrap_err();
        assert!(err.to_string().contains("does not match"), "{err}");
    }

    #[test]
    fn mismatched_input_resolution_is_typed_error() {
        // the plan is resolved for the graph's declared input; a batch at
        // another resolution must be a typed error, not a silent garbage
        // geometry
        let (graph, folded, spec) = resnet_like();
        let eng = IntEngine::new(&graph, &folded, &spec);
        let err = eng.run(&Tensor::zeros(&[1, 8, 8, 2])).unwrap_err();
        assert!(matches!(err, DfqError::InvalidInput(_)), "{err}");
    }
}
