//! The integer-only inference engine — the paper's custom hardware unit
//! in software (Eq. 3–4):
//!
//! * weights/biases/activations are n-bit integer codes (held in i32
//!   lanes), accumulation is 32-bit;
//! * biases are aligned into the accumulator domain by a left shift of
//!   `(N_x + N_w) − N_b` (Eq. 3), residuals by `(N_x + N_w) − N_r`;
//! * the output is requantized with a rounded right shift of
//!   `(N_x + N_w) − N_o` and clamped — to the unsigned range after a
//!   fused ReLU (Fig. 1 b/c), the signed range otherwise;
//! * global-average-pool divides by a power-of-two spatial size with the
//!   same rounded shift, so the whole network is exact integer math.
//!
//! Bit-exact with `python/compile/kernels/ref.py` (and therefore with the
//! Pallas kernels and the AOT artifacts) — integration tests chain all
//! three.
//!
//! The engine also supports the **unfused** ablation (DESIGN.md §7):
//! quantization immediately after the conv accumulator and again after
//! the residual add — the strategy the paper's Fig.-1 restructuring
//! removes. It needs extra calibrated scales (`pre_frac`), supplied by
//! the ablation calibrator.

use std::collections::HashMap;

use crate::graph::bn_fold::FoldedParams;
use crate::graph::{Graph, ModuleKind};
use crate::quant::params::QuantSpec;
use crate::quant::scheme;
use crate::tensor::im2col::Padding;
use crate::tensor::{ops_int, Tensor, TensorI32};

/// Quantized parameters of one module, ready for the integer engine.
#[derive(Clone, Debug)]
pub struct QuantizedParams {
    /// weight codes (HWIO conv / (Cin,Cout) dense)
    pub w: TensorI32,
    /// bias codes
    pub b: Vec<i32>,
}

/// Quantize all folded parameters per a spec (shared by the engine and
/// the PJRT path, so both feed identical codes).
pub fn quantize_params(
    graph: &Graph,
    folded: &HashMap<String, FoldedParams>,
    spec: &QuantSpec,
) -> HashMap<String, QuantizedParams> {
    let mut out = HashMap::new();
    for m in graph.weight_modules() {
        // during joint calibration only a prefix of the graph is
        // calibrated; quantize what the spec covers
        let Some(&s) = spec.modules.get(&m.name) else { continue };
        let p = &folded[&m.name];
        let w = scheme::quantize_tensor(&p.w, s.n_w, spec.n_bits, false);
        let b: Vec<i32> = p
            .b
            .iter()
            .map(|&x| scheme::quantize_val(x, s.n_b, spec.n_bits, false))
            .collect();
        out.insert(m.name.clone(), QuantizedParams { w, b });
    }
    out
}

/// The integer-only executor.
pub struct IntEngine<'g> {
    graph: &'g Graph,
    spec: &'g QuantSpec,
    qparams: std::borrow::Cow<'g, HashMap<String, QuantizedParams>>,
    /// unfused ablation: per-module fractional bits of the intermediate
    /// (pre-ReLU / pre-add) quantization points
    pub pre_frac: Option<HashMap<String, i32>>,
}

impl<'g> IntEngine<'g> {
    /// Build: quantizes the folded weights once.
    pub fn new(
        graph: &'g Graph,
        folded: &HashMap<String, FoldedParams>,
        spec: &'g QuantSpec,
    ) -> Self {
        let qparams = std::borrow::Cow::Owned(quantize_params(graph, folded, spec));
        IntEngine { graph, spec, qparams, pre_frac: None }
    }

    /// Build over parameters already quantized by [`quantize_params`] —
    /// lets long-lived callers (the serving engines) pay the weight
    /// quantization once instead of per batch.
    pub fn with_qparams(
        graph: &'g Graph,
        spec: &'g QuantSpec,
        qparams: &'g HashMap<String, QuantizedParams>,
    ) -> Self {
        IntEngine { graph, spec, qparams: std::borrow::Cow::Borrowed(qparams), pre_frac: None }
    }

    /// Access the quantized parameters (the PJRT path feeds these to the
    /// q_logits artifact).
    pub fn qparams(&self) -> &HashMap<String, QuantizedParams> {
        &self.qparams
    }

    /// Quantize a normalised f32 input batch into codes.
    pub fn quantize_input(&self, x: &Tensor) -> TensorI32 {
        scheme::quantize_tensor(x, self.spec.input_frac, self.spec.n_bits, false)
    }

    /// Run on input codes, returning every module's codes.
    pub fn run_acts(&self, x_int: &TensorI32) -> HashMap<String, TensorI32> {
        let mut acts: HashMap<String, TensorI32> = HashMap::new();
        acts.insert("input".to_string(), x_int.clone());
        for m in &self.graph.modules {
            let out = self.run_module(m, &acts);
            acts.insert(m.name.clone(), out);
        }
        acts
    }

    /// Execute one module given the activations so far.
    pub fn run_module(
        &self,
        m: &crate::graph::UnifiedModule,
        acts: &HashMap<String, TensorI32>,
    ) -> TensorI32 {
        let src = &acts[&m.src];
        let n_bits = self.spec.n_bits;
        match &m.kind {
            ModuleKind::Gap => {
                let sum = ops_int::global_sum_pool(src);
                let hw = src.shape.dim(1) * src.shape.dim(2);
                debug_assert!(hw.is_power_of_two());
                let s = hw.trailing_zeros() as i32;
                let unsigned = self.spec.value_unsigned(self.graph, &m.src);
                let (qmin, qmax) = scheme::qrange(n_bits, unsigned);
                sum.map_i32_ref(|v| scheme::shift_round(v, s).clamp(qmin, qmax))
            }
            ModuleKind::Conv { .. } | ModuleKind::Dense { .. } => {
                let sp = self.spec.modules[&m.name];
                let n_x = self.spec.value_frac(self.graph, &m.src);
                let qp = &self.qparams[&m.name];
                let mut acc = match &m.kind {
                    ModuleKind::Conv { stride, .. } => {
                        ops_int::conv2d_acc(src, &qp.w, *stride, Padding::Same)
                    }
                    ModuleKind::Dense { .. } => {
                        let flat = src.reshape(&[
                            src.shape.dim(0),
                            src.numel() / src.shape.dim(0),
                        ]);
                        ops_int::dense_acc(&flat, &qp.w)
                    }
                    ModuleKind::Gap => unreachable!(),
                };
                let bias_shift = sp.bias_shift(n_x);
                let cout = *acc.shape.dims().last().unwrap();
                let aligned: Vec<i32> =
                    qp.b.iter().map(|&b| scheme::align(b, bias_shift)).collect();
                if let Some(pre) = &self.pre_frac {
                    // ----- unfused ablation: extra quantization points -----
                    for chunk in acc.data.chunks_exact_mut(cout) {
                        for (v, a) in chunk.iter_mut().zip(&aligned) {
                            *v = v.wrapping_add(*a);
                        }
                    }
                    return self.run_epilogue_unfused(m, acc, acts, pre, n_x, sp);
                }
                // fused epilogue: bias-add (+ residual-align-add) + shift
                // + clamp in ONE pass over the accumulator, in place —
                // the software analogue of the paper's "without writing
                // the convolution output back to memory" (§Perf log #2).
                let out_shift = sp.out_shift(n_x);
                let (qmin, qmax) = scheme::qrange(n_bits, m.relu);
                match &m.res {
                    Some(r) => {
                        let n_r = self.spec.value_frac(self.graph, r);
                        let rs = sp.res_shift(n_x, n_r);
                        let rt = &acts[r];
                        debug_assert_eq!(rt.numel(), acc.numel());
                        for (row, chunk) in acc.data.chunks_exact_mut(cout).enumerate() {
                            let rrow = &rt.data[row * cout..(row + 1) * cout];
                            for (j, v) in chunk.iter_mut().enumerate() {
                                let a = v
                                    .wrapping_add(aligned[j])
                                    .wrapping_add(scheme::align(rrow[j], rs));
                                *v = scheme::shift_round(a, out_shift).clamp(qmin, qmax);
                            }
                        }
                    }
                    None => {
                        for chunk in acc.data.chunks_exact_mut(cout) {
                            for (j, v) in chunk.iter_mut().enumerate() {
                                let a = v.wrapping_add(aligned[j]);
                                *v = scheme::shift_round(a, out_shift).clamp(qmin, qmax);
                            }
                        }
                    }
                }
                acc
            }
        }
    }

    /// The ablation epilogue: requantize the conv output immediately
    /// (extra quantization op), then align + add the residual in the
    /// *code* domain, then requantize again (another extra op) — the
    /// "quantize instantly after convolution" dataflow of prior work.
    fn run_epilogue_unfused(
        &self,
        m: &crate::graph::UnifiedModule,
        acc: TensorI32,
        acts: &HashMap<String, TensorI32>,
        pre: &HashMap<String, i32>,
        n_x: i32,
        sp: crate::quant::params::ModuleShifts,
    ) -> TensorI32 {
        let n_bits = self.spec.n_bits;
        let n_pre = *pre.get(&m.name).unwrap_or(&sp.n_o);
        // quant point #1: conv output -> codes at scale n_pre (signed)
        let conv_codes =
            scheme::requantize_tensor(&acc, n_x + sp.n_w - n_pre, n_bits, false);
        let mut cur = conv_codes;
        if let Some(r) = &m.res {
            let n_r = self.spec.value_frac(self.graph, r);
            let rt = &acts[r];
            // align residual codes to n_pre and add, then quant point #2
            let mut sum: Vec<i32> = cur
                .data
                .iter()
                .zip(&rt.data)
                .map(|(&a, &b)| a.wrapping_add(scheme::shift_round(b, n_r - n_pre)))
                .collect();
            let (qmin, qmax) = scheme::qrange(n_bits, false);
            for v in &mut sum {
                *v = (*v).clamp(qmin * 2, qmax * 2); // 9-bit intermediate
            }
            cur = TensorI32 { shape: cur.shape.clone(), data: sum };
        }
        // final requant to n_o (+relu clamp) — quant point #2/#3
        let (qmin, qmax) = scheme::qrange(n_bits, m.relu);
        cur.map_i32_ref(|v| scheme::shift_round(v, n_pre - sp.n_o).clamp(qmin, qmax))
    }

    /// Full pipeline from a normalised f32 batch to final output codes.
    pub fn run(&self, x: &Tensor) -> TensorI32 {
        let xq = self.quantize_input(x);
        let mut acts = self.run_acts(&xq);
        acts.remove(&self.graph.modules.last().unwrap().name).unwrap()
    }

    /// Final logits dequantized to f32 (for metrics that need scores).
    pub fn run_dequant(&self, x: &Tensor) -> Tensor {
        let last = &self.graph.modules.last().unwrap().name;
        let out = self.run(x);
        scheme::dequantize_tensor(&out, self.spec.value_frac(self.graph, last))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::UnifiedModule;
    use crate::quant::params::ModuleShifts;

    /// Hand-checkable single conv: x scale 2^-4, w scale 2^-6, bias 2^-5,
    /// out 2^-3.
    #[test]
    fn single_conv_matches_hand_math() {
        let graph = Graph {
            name: "t".into(),
            input_hwc: (1, 1, 1),
            modules: vec![UnifiedModule {
                name: "c".into(),
                kind: ModuleKind::Conv { kh: 1, kw: 1, cin: 1, cout: 1, stride: 1 },
                src: "input".into(),
                res: None,
                relu: false,
            }],
        };
        let mut folded = HashMap::new();
        folded.insert(
            "c".to_string(),
            FoldedParams { w: Tensor::from_vec(&[1, 1, 1, 1], vec![0.75]), b: vec![0.5] },
        );
        let mut spec = QuantSpec::new(8);
        spec.input_frac = 4;
        spec.modules.insert("c".into(), ModuleShifts { n_w: 6, n_b: 5, n_o: 3 });
        let eng = IntEngine::new(&graph, &folded, &spec);
        // x = 1.25 -> code 20; w = 0.75 -> code 48; b = 0.5 -> code 16
        let x = Tensor::from_vec(&[1, 1, 1, 1], vec![1.25]);
        let out = eng.run(&x);
        // acc = 20*48 + (16 << (4+6-5)) = 960 + 512 = 1472 at scale 2^-10
        // out = round(1472 / 2^(10-3)) = round(11.5) = 12 -> 1.5 at 2^-3
        assert_eq!(out.data[0], 12);
        let deq = eng.run_dequant(&x);
        assert!((deq.data[0] - 1.5).abs() < 1e-6);
    }

    /// The engine must agree with a float-side simulation of Q for a
    /// random fused residual module.
    #[test]
    fn residual_module_exactness_vs_scheme_sim() {
        let graph = Graph {
            name: "t".into(),
            input_hwc: (4, 4, 2),
            modules: vec![
                UnifiedModule {
                    name: "c0".into(),
                    kind: ModuleKind::Conv { kh: 1, kw: 1, cin: 2, cout: 2, stride: 1 },
                    src: "input".into(),
                    res: None,
                    relu: true,
                },
                UnifiedModule {
                    name: "c1".into(),
                    kind: ModuleKind::Conv { kh: 3, kw: 3, cin: 2, cout: 2, stride: 1 },
                    src: "c0".into(),
                    res: Some("c0".into()),
                    relu: true,
                },
            ],
        };
        let mut rng = crate::util::rng::Pcg::new(11);
        let mut folded = HashMap::new();
        for name in ["c0", "c1"] {
            let k = if name == "c0" { 1 } else { 3 };
            let w = Tensor::from_vec(
                &[k, k, 2, 2],
                (0..k * k * 4).map(|_| rng.normal_ms(0.0, 0.4)).collect(),
            );
            folded.insert(
                name.to_string(),
                FoldedParams { w, b: vec![rng.normal_ms(0.0, 0.2), rng.normal_ms(0.0, 0.2)] },
            );
        }
        let mut spec = QuantSpec::new(8);
        spec.input_frac = 5;
        spec.modules.insert("c0".into(), ModuleShifts { n_w: 7, n_b: 7, n_o: 5 });
        spec.modules.insert("c1".into(), ModuleShifts { n_w: 7, n_b: 7, n_o: 4 });
        let eng = IntEngine::new(&graph, &folded, &spec);
        let x = Tensor::from_vec(&[1, 4, 4, 2], (0..32).map(|_| rng.normal()).collect());
        let acts = eng.run_acts(&eng.quantize_input(&x));
        // every activation is inside its clamp range
        for name in ["c0", "c1"] {
            let (qmin, qmax) = scheme::qrange(8, true);
            for &v in &acts[name].data {
                assert!(v >= qmin && v <= qmax);
            }
        }
        // and c1's codes dequantize close to the FP engine's output
        let fpe = crate::engine::fp::FpEngine::new(&graph, &folded);
        let facts = fpe.run_acts(&x);
        let deq = scheme::dequantize_tensor(&acts["c1"], 4);
        let mse = crate::util::mathutil::mse(&deq.data, &facts["c1"].data);
        assert!(mse < 0.01, "integer path diverged: mse={mse}");
    }

    #[test]
    fn unfused_mode_runs_and_differs() {
        // same graph as above; the ablation engine should produce valid
        // codes that (generally) differ from the fused ones.
        let graph = Graph {
            name: "t".into(),
            input_hwc: (4, 4, 2),
            modules: vec![
                UnifiedModule {
                    name: "c0".into(),
                    kind: ModuleKind::Conv { kh: 3, kw: 3, cin: 2, cout: 2, stride: 1 },
                    src: "input".into(),
                    res: None,
                    relu: true,
                },
            ],
        };
        let mut rng = crate::util::rng::Pcg::new(13);
        let mut folded = HashMap::new();
        folded.insert(
            "c0".to_string(),
            FoldedParams {
                w: Tensor::from_vec(&[3, 3, 2, 2], (0..36).map(|_| rng.normal_ms(0.0, 0.4)).collect()),
                b: vec![0.1, -0.1],
            },
        );
        let mut spec = QuantSpec::new(8);
        spec.input_frac = 5;
        spec.modules.insert("c0".into(), ModuleShifts { n_w: 7, n_b: 7, n_o: 5 });
        let mut eng = IntEngine::new(&graph, &folded, &spec);
        let x = Tensor::from_vec(&[1, 4, 4, 2], (0..32).map(|_| rng.normal()).collect());
        let fused = eng.run(&x);
        let mut pre = HashMap::new();
        pre.insert("c0".to_string(), 3); // coarse intermediate scale
        eng.pre_frac = Some(pre);
        let unfused = eng.run(&x);
        assert_eq!(fused.shape, unfused.shape);
        // coarse pre-quantization loses information vs the fused path
        assert_ne!(fused.data, unfused.data);
    }
}
