//! Plan **executors**: the generic step loop + buffer-slot arena that
//! runs a compiled [`ExecPlan`], and the two kernel domains it is
//! generic over — `IntDomain` (i32 codes, Eq. 3–4 shift epilogues) and
//! `FpDomain` (f32 oracle arithmetic, bit-identical to the historical
//! interpreter's op order).
//!
//! The executor performs **no name or shape resolution**: every step
//! addresses integer buffer slots assigned at compile time, so a single
//! in-flight pass owns exactly `plan.slot_count()` live buffers (one
//! [`Scratch`] arena per executor — the buffer-reuse contract). Kernels
//! are shared with the per-module interpreter path
//! ([`crate::engine::int::IntEngine::run_module`], kept for the
//! calibrator's prefix probing), so the two paths cannot drift.

use std::collections::HashMap;

use crate::engine::int::QuantizedParams;
use crate::engine::plan::{ConvOp, DenseOp, ExecPlan, GapOp, GemmStep, Op, QuantEpi};
use crate::error::DfqError;
use crate::quant::scheme;
use crate::tensor::im2col::{im2col_slice_into, Padding};
use crate::tensor::kernels::{self, FusedEpi, PackedGemm};
use crate::tensor::{ops, ops_int};

// ---------------------------------------------------------------------
// the scratch arena
// ---------------------------------------------------------------------

/// Reusable working memory for one executor pass: the im2col patch
/// matrix plus a free-list of recycled activation/accumulator buffers.
/// A warm scratch makes repeated passes allocation-free for the large
/// tensors.
///
/// A `Scratch` is plain owned memory — `Send` but deliberately not
/// shared: one scratch serves one pass at a time (the parallel deploy
/// engine keeps a pool of them, one per in-flight shard).
pub struct Scratch<T = i32> {
    pub(crate) patches: Vec<T>,
    free: Vec<Vec<T>>,
    /// the executor's slot table, kept between passes so the warm path
    /// never allocates it (cells are always `None` between passes)
    slots: Vec<Option<Vec<T>>>,
}

impl<T> Default for Scratch<T> {
    fn default() -> Self {
        Scratch { patches: Vec::new(), free: Vec::new(), slots: Vec::new() }
    }
}

impl<T: Copy + Default> Scratch<T> {
    /// An empty arena (buffers grow on first use).
    pub fn new() -> Scratch<T> {
        Scratch::default()
    }

    /// Return a buffer to the free list for reuse by a later step or
    /// pass (no-op for buffers that never allocated).
    pub fn recycle(&mut self, buf: Vec<T>) {
        if buf.capacity() > 0 {
            self.free.push(buf);
        }
    }

    /// A buffer of exactly `len` elements, **every element zeroed**
    /// (`T::default()`). Use for consumers that accumulate in place
    /// (e.g. the pooling sums); full-overwrite consumers should call
    /// [`Scratch::take_uninit`] and skip the redundant fill.
    pub fn take(&mut self, len: usize) -> Vec<T> {
        match self.free.pop() {
            Some(mut v) => {
                v.clear();
                v.resize(len, T::default());
                v
            }
            None => vec![T::default(); len],
        }
    }

    /// A buffer of exactly `len` elements whose reused prefix holds
    /// **unspecified (stale) contents** — still safe, never
    /// uninitialized memory, but only correct when the caller's contract
    /// guarantees every element is overwritten before it is read (the
    /// GEMM regimes, im2col, input quantization). Skips the redundant
    /// per-step memset on the steady-state hot path.
    pub fn take_uninit(&mut self, len: usize) -> Vec<T> {
        match self.free.pop() {
            Some(mut v) => {
                v.truncate(len);
                v.resize(len, T::default());
                v
            }
            None => vec![T::default(); len],
        }
    }
}

// ---------------------------------------------------------------------
// the kernel domain + generic executor
// ---------------------------------------------------------------------

/// One numeric kernel domain the plan executor is generic over: the
/// element type plus the three compute kernels (each reads the resolved
/// instruction plus raw slices — no names, no shape checks). Kernels
/// are fallible so a plan whose constants are missing (an fp plan bound
/// to the int domain) surfaces as a typed error, never a panic.
#[allow(clippy::too_many_arguments)]
pub(crate) trait Domain {
    /// element type flowing through the buffers
    type Elem: Copy + Default;

    /// im2col conv + epilogue into `out` (`n * ho * wo * cout` elems).
    fn conv(
        &self,
        c: &ConvOp,
        n: usize,
        src: &[Self::Elem],
        res: Option<&[Self::Elem]>,
        out: &mut Vec<Self::Elem>,
        patches: &mut Vec<Self::Elem>,
        threads: usize,
    ) -> Result<(), DfqError>;

    /// dense GEMM + epilogue into `out` (`n * cout` elems).
    fn dense(
        &self,
        d: &DenseOp,
        n: usize,
        src: &[Self::Elem],
        res: Option<&[Self::Elem]>,
        out: &mut Vec<Self::Elem>,
        threads: usize,
    ) -> Result<(), DfqError>;

    /// global average pool into `out` (`n * c` elems, pre-zeroed).
    fn gap(
        &self,
        g: &GapOp,
        n: usize,
        src: &[Self::Elem],
        out: &mut [Self::Elem],
    ) -> Result<(), DfqError>;

    /// Cross-check a step's runtime output against the interval the
    /// static verifier proved for it (`plan.ranges`, populated in debug
    /// builds for integer plans). Default: no-op — the int domain
    /// overrides it in debug builds, catching verifier unsoundness and
    /// executor drift in one guard.
    fn check_range(&self, _step: &str, _range: Option<(i32, i32)>, _out: &[Self::Elem]) {}
}

/// Run a compiled plan over one batch: `input` is the input value's
/// buffer (`n * plan.input_elems`, already in the domain's element
/// type), and the returned buffer is the final value's
/// (`n * plan.out_elems()`). Dead buffers are recycled into `scratch`
/// as their last consumer retires, so a warm scratch makes steady-state
/// execution allocation-free.
pub(crate) fn execute<D: Domain>(
    plan: &ExecPlan,
    dom: &D,
    input: Vec<D::Elem>,
    n: usize,
    scratch: &mut Scratch<D::Elem>,
    threads: usize,
) -> Result<Vec<D::Elem>, DfqError> {
    let want = n * plan.input_shape.elems();
    if input.len() != want {
        return Err(bad_input_err(input.len(), want, n, plan));
    }
    // the slot table lives in the scratch between passes (warm path
    // allocates nothing); cells are None between passes, but drain
    // defensively in case a previous pass error-returned mid-plan
    let mut slots = std::mem::take(&mut scratch.slots);
    for cell in slots.iter_mut() {
        if let Some(buf) = cell.take() {
            scratch.recycle(buf);
        }
    }
    slots.resize_with(plan.slot_count, || None);
    let Some(cell) = slots.get_mut(plan.input_slot) else {
        return Err(dead_slot_err("<input>", "input", plan.input_slot));
    };
    *cell = Some(input);
    for (i, step) in plan.steps.iter().enumerate() {
        let out_len = n * step.out.elems();
        // Gap accumulates in place and needs zeros; the GEMM steps
        // overwrite every element (take_uninit contract)
        let mut out = match &step.op {
            Op::Gap(_) => scratch.take(out_len),
            _ => scratch.take_uninit(out_len),
        };
        let Some(src) = slots.get(step.src).and_then(|c| c.as_deref()) else {
            return Err(dead_slot_err(&step.name, "src", step.src));
        };
        let res = match step.res {
            Some(slot) => match slots.get(slot).and_then(|c| c.as_deref()) {
                Some(r) => Some(r),
                None => return Err(dead_slot_err(&step.name, "res", slot)),
            },
            None => None,
        };
        // detach the patch buffer so the kernel can borrow it mutably
        // alongside the immutable slot views
        let mut patches = std::mem::take(&mut scratch.patches);
        let ran = match &step.op {
            Op::Conv(c) => dom.conv(c, n, src, res, &mut out, &mut patches, threads),
            Op::Dense(d) => dom.dense(d, n, src, res, &mut out, threads),
            Op::Gap(g) => dom.gap(g, n, src, &mut out),
        };
        scratch.patches = patches;
        ran?;
        // debug-build cross-validation of static range vs runtime values
        // (plan.ranges is empty in release: None -> default no-op)
        dom.check_range(&step.name, plan.ranges.get(i).copied(), &out);
        let Some(cell) = slots.get_mut(step.dst) else {
            return Err(dead_slot_err(&step.name, "dst", step.dst));
        };
        *cell = Some(out);
        for &s in &step.release {
            if let Some(buf) = slots.get_mut(s).and_then(|c| c.take()) {
                scratch.recycle(buf);
            }
        }
    }
    let Some(out) = slots.get_mut(plan.out_slot).and_then(|c| c.take()) else {
        return Err(dead_slot_err("<output>", "output", plan.out_slot));
    };
    scratch.slots = slots;
    Ok(out)
}

/// Out-of-line constructor for the (cold) input-shape mismatch error —
/// keeps the formatting machinery off the warm path.
#[cold]
#[inline(never)]
fn bad_input_err(got: usize, want: usize, n: usize, plan: &ExecPlan) -> DfqError {
    DfqError::invalid(format!(
        "plan input has {got} elements, expected {want} (batch {n} of {})",
        plan.input_shape
    ))
}

/// Out-of-line constructor for the (cold) corrupt-slot-schedule error.
/// Unreachable for any plan `ExecPlan::compile` produced — the static
/// verifier proves slot safety in debug builds — but a typed error beats
/// a panic if a hand-built plan ever gets here.
#[cold]
#[inline(never)]
fn dead_slot_err(step: &str, role: &str, slot: usize) -> DfqError {
    DfqError::graph(format!(
        "{step}: {role} slot s{slot} holds no live buffer — the plan's slot \
         schedule is corrupt (`dfq verify` rejects such plans)"
    ))
}

/// Out-of-line constructor for the (cold) missing-epilogue error: an fp
/// plan's step reached an integer kernel.
#[cold]
#[inline(never)]
fn no_epilogue_err() -> DfqError {
    DfqError::graph(
        "integer plan step has no epilogue constants (was an fp plan bound \
         to the integer engine?)",
    )
}

// ---------------------------------------------------------------------
// integer domain (Eq. 3–4)
// ---------------------------------------------------------------------

/// One weighted step's bound integer parameters: weight codes plus the
/// bias codes **pre-aligned** into the accumulator domain (the
/// `align(b, bias_shift)` the interpreter recomputed per batch).
#[derive(Clone, Copy)]
pub(crate) struct IntStepView<'a> {
    /// weight codes, flattened `(K, cout)` row-major
    pub w: &'a [i32],
    /// accumulator-domain bias codes, one per output channel
    pub b: &'a [i32],
    /// bind-time packed panels for the fused kernel — `None` keeps the
    /// step on the reference GEMM + `int_epilogue` path
    pub packed: Option<&'a PackedGemm>,
}

/// The i32 kernel domain: parameter views indexed by the plan's
/// parameter table.
pub(crate) struct IntDomain<'a> {
    /// per-param views, in [`ExecPlan::param_names`] order
    pub params: &'a [IntStepView<'a>],
}

/// Validate a quantized parameter map against a plan and produce the
/// accumulator-aligned bias vectors, one per parameter-table entry.
/// All coverage/shape errors surface here (bind time), not per batch.
pub(crate) fn aligned_biases(
    plan: &ExecPlan,
    qparams: &HashMap<String, QuantizedParams>,
) -> Result<Vec<Vec<i32>>, DfqError> {
    let mut out = vec![Vec::new(); plan.param_names().len()];
    for step in &plan.steps {
        let g = match &step.op {
            Op::Conv(c) => &c.g,
            Op::Dense(d) => &d.g,
            Op::Gap(_) => continue,
        };
        let name = &plan.param_names()[g.param];
        let qp = qparams.get(name).ok_or_else(|| {
            DfqError::graph(format!("module '{name}' has no quantized parameters"))
        })?;
        if qp.w.data.len() != g.kdim * g.cout {
            return Err(DfqError::graph(format!(
                "module '{name}': weight shape {} does not match the plan's \
                 {}x{} GEMM",
                qp.w.shape, g.kdim, g.cout
            )));
        }
        if qp.b.len() != g.cout {
            return Err(DfqError::graph(format!(
                "module '{name}': {} bias codes for {} output channels",
                qp.b.len(),
                g.cout
            )));
        }
        let Some(q) = g.q else {
            return Err(DfqError::graph(format!(
                "module '{name}': integer parameters bound to a plan step \
                 with no epilogue constants (fp plan?)"
            )));
        };
        out[g.param] = qp.b.iter().map(|&b| scheme::align(b, q.bias_shift)).collect();
    }
    Ok(out)
}

/// Build the per-param views over a quantized parameter map and the
/// aligned biases from [`aligned_biases`]. Infallible once bound.
/// `packed` is the bind-time panel table from [`pack_plan`] — pass an
/// empty slice to keep every step on the reference kernels.
pub(crate) fn int_views<'a>(
    plan: &ExecPlan,
    qparams: &'a HashMap<String, QuantizedParams>,
    biases: &'a [Vec<i32>],
    packed: &'a [PackedGemm],
) -> Vec<IntStepView<'a>> {
    plan.param_names()
        .iter()
        .zip(biases)
        .enumerate()
        .map(|(i, (name, b))| IntStepView {
            w: &qparams[name].w.data,
            b,
            packed: packed.get(i),
        })
        .collect()
}

/// Pre-pack every weighted step's weight codes into the cache-friendly
/// column panels its compile-time [`crate::engine::plan::KernelChoice`]
/// declared — the bind-time half of kernel emission (once per plan, not
/// per batch). Returns an empty table for plans whose steps all selected
/// the reference kernels (fp / unfused-ablation plans), so binding costs
/// nothing there. Coverage/shape errors surface in [`aligned_biases`];
/// this reports only the (statically impossible, still checked)
/// narrowing failure.
pub(crate) fn pack_plan(
    plan: &ExecPlan,
    qparams: &HashMap<String, QuantizedParams>,
) -> Result<Vec<PackedGemm>, DfqError> {
    let mut out = Vec::with_capacity(plan.param_names().len());
    for step in &plan.steps {
        let g = match &step.op {
            Op::Conv(c) => &c.g,
            Op::Dense(d) => &d.g,
            Op::Gap(_) => continue,
        };
        if !g.kernel.fused {
            return Ok(Vec::new());
        }
        let name = &plan.param_names()[g.param];
        let qp = qparams.get(name).ok_or_else(|| {
            DfqError::graph(format!("module '{name}' has no quantized parameters"))
        })?;
        debug_assert_eq!(out.len(), g.param);
        out.push(kernels::pack_panels(
            &qp.w.data,
            g.kdim,
            g.cout,
            g.kernel.pack,
        )?);
    }
    Ok(out)
}

/// The fused-epilogue constants of a step, for
/// [`kernels::fused_gemm_into`] (the non-ablation subset of `QuantEpi`).
#[inline]
fn fused_epi(q: &QuantEpi) -> FusedEpi {
    FusedEpi {
        out_shift: q.out_shift,
        res_shift: q.res_shift,
        qmin: q.qmin,
        qmax: q.qmax,
    }
}

/// The shared integer GEMM epilogue — fused (bias + residual align +
/// shift + clamp in one in-place pass) or the unfused ablation. Called
/// by both the plan executor and the per-module interpreter path, so the
/// two cannot drift.
pub(crate) fn int_epilogue(
    q: &QuantEpi,
    cout: usize,
    b_aligned: &[i32],
    res: Option<&[i32]>,
    acc: &mut [i32],
) {
    if let Some(u) = q.unfused {
        // ----- unfused ablation: extra quantization points -----
        for chunk in acc.chunks_exact_mut(cout) {
            for (v, b) in chunk.iter_mut().zip(b_aligned) {
                *v = v.wrapping_add(*b);
            }
        }
        // quant point #1: accumulator -> codes at the intermediate scale
        for v in acc.iter_mut() {
            *v = scheme::shift_round(*v, u.pre_shift).clamp(u.pre_qmin, u.pre_qmax);
        }
        if let Some(r) = res {
            // align residual codes to the intermediate scale and add,
            // clamped to the 9-bit intermediate
            for (v, &rv) in acc.iter_mut().zip(r) {
                *v = v
                    .wrapping_add(scheme::shift_round(rv, u.res_align))
                    .clamp(u.mid_qmin, u.mid_qmax);
            }
        }
        // final requant to n_o (+relu clamp) — quant point #2/#3
        for v in acc.iter_mut() {
            *v = scheme::shift_round(*v, u.final_shift).clamp(q.qmin, q.qmax);
        }
        return;
    }
    // fused epilogue: bias-add (+ residual-align-add) + shift + clamp in
    // ONE pass over the accumulator, in place — the software analogue of
    // the paper's "without writing the convolution output back to memory"
    match res {
        Some(r) => {
            for (row, chunk) in acc.chunks_exact_mut(cout).enumerate() {
                let rrow = &r[row * cout..(row + 1) * cout];
                for (j, v) in chunk.iter_mut().enumerate() {
                    let a = v
                        .wrapping_add(b_aligned[j])
                        .wrapping_add(scheme::align(rrow[j], q.res_shift));
                    *v = scheme::shift_round(a, q.out_shift).clamp(q.qmin, q.qmax);
                }
            }
        }
        None => {
            for chunk in acc.chunks_exact_mut(cout) {
                for (j, v) in chunk.iter_mut().enumerate() {
                    let a = v.wrapping_add(b_aligned[j]);
                    *v = scheme::shift_round(a, q.out_shift).clamp(q.qmin, q.qmax);
                }
            }
        }
    }
}

/// The shared integer pooling kernel: wrapping sums over the window into
/// the pre-zeroed `out`, then the exact rounded-shift mean + clamp
/// (`clamp` is the step's resolved code range — callers extract it from
/// `GapOp::clamp` so a missing constant is a typed bind/step error).
pub(crate) fn int_gap(g: &GapOp, clamp: (i32, i32), n: usize, src: &[i32], out: &mut [i32]) {
    sum_pool(n, g.h, g.w, g.c, src, out, |a, b| a.wrapping_add(b));
    let (qmin, qmax) = clamp;
    for v in out.iter_mut() {
        *v = scheme::shift_round(*v, g.shift).clamp(qmin, qmax);
    }
}

impl Domain for IntDomain<'_> {
    type Elem = i32;

    fn conv(
        &self,
        c: &ConvOp,
        n: usize,
        src: &[i32],
        res: Option<&[i32]>,
        out: &mut Vec<i32>,
        patches: &mut Vec<i32>,
        threads: usize,
    ) -> Result<(), DfqError> {
        let Some(q) = &c.g.q else { return Err(no_epilogue_err()) };
        let p = &self.params[c.g.param];
        let m = n * c.ho * c.wo;
        // exact-size take_uninit upstream: the GEMM overwrites every
        // element, no zero fill needed
        debug_assert_eq!(out.len(), m * c.g.cout);
        if let Some(pk) = p.packed {
            if q.unfused.is_none() {
                // emitted kernel: packed panels, epilogue fused in-tile
                if c.g.kernel.elide_im2col {
                    // 1x1 stride-1 SAME: the patch matrix IS the input
                    // buffer — run the GEMM over the activation in place
                    kernels::fused_gemm_into(
                        src,
                        pk,
                        p.b,
                        res,
                        fused_epi(q),
                        m,
                        out,
                        threads,
                    );
                    return Ok(());
                }
                im2col_slice_into(
                    src, n, c.in_h, c.in_w, c.cin, c.kh, c.kw, c.stride, Padding::Same,
                    patches,
                );
                kernels::fused_gemm_into(
                    &patches[..m * c.g.kdim],
                    pk,
                    p.b,
                    res,
                    fused_epi(q),
                    m,
                    out,
                    threads,
                );
                return Ok(());
            }
        }
        im2col_slice_into(
            src, n, c.in_h, c.in_w, c.cin, c.kh, c.kw, c.stride, Padding::Same, patches,
        );
        ops_int::gemm_i32_into(
            &patches[..m * c.g.kdim],
            p.w,
            m,
            c.g.kdim,
            c.g.cout,
            out,
            threads,
        );
        int_epilogue(q, c.g.cout, p.b, res, out);
        Ok(())
    }

    fn dense(
        &self,
        d: &DenseOp,
        n: usize,
        src: &[i32],
        res: Option<&[i32]>,
        out: &mut Vec<i32>,
        threads: usize,
    ) -> Result<(), DfqError> {
        let Some(q) = &d.g.q else { return Err(no_epilogue_err()) };
        let p = &self.params[d.g.param];
        if let Some(pk) = p.packed {
            if q.unfused.is_none() {
                kernels::fused_gemm_into(src, pk, p.b, res, fused_epi(q), n, out, threads);
                return Ok(());
            }
        }
        ops_int::gemm_i32_into(src, p.w, n, d.g.kdim, d.g.cout, out, threads);
        int_epilogue(q, d.g.cout, p.b, res, out);
        Ok(())
    }

    fn gap(&self, g: &GapOp, n: usize, src: &[i32], out: &mut [i32]) -> Result<(), DfqError> {
        let Some(clamp) = g.clamp else { return Err(no_epilogue_err()) };
        int_gap(g, clamp, n, src, out);
        Ok(())
    }

    /// The cross-validation guard (debug builds only): every runtime
    /// output value must lie inside the interval the static verifier
    /// proved for the step. A violation means the verifier is unsound or
    /// the executor drifted from the Eq. 3–4 algebra it models.
    #[cfg(debug_assertions)]
    fn check_range(&self, step: &str, range: Option<(i32, i32)>, out: &[i32]) {
        let Some((lo, hi)) = range else { return };
        for &v in out {
            assert!(
                v >= lo && v <= hi,
                "{step}: runtime value {v} escapes the statically verified \
                 range [{lo}, {hi}]"
            );
        }
    }
}

// ---------------------------------------------------------------------
// floating-point domain (the oracle)
// ---------------------------------------------------------------------

/// One weighted step's folded f32 parameters.
#[derive(Clone, Copy)]
pub(crate) struct FpStepView<'a> {
    /// folded weights, flattened `(K, cout)` row-major
    pub w: &'a [f32],
    /// folded bias, one per output channel
    pub b: &'a [f32],
}

/// The f32 kernel domain. Arithmetic order is identical to the
/// historical interpreter (`gemm`, then `+bias`, then `+residual`, then
/// ReLU), so plan execution is bit-identical to
/// [`crate::engine::fp::FpEngine::run_acts`].
pub(crate) struct FpDomain<'a> {
    /// per-param views, in [`ExecPlan::param_names`] order
    pub params: &'a [FpStepView<'a>],
}

/// Validate a folded parameter map against a plan and produce the
/// per-param views. All coverage/shape errors surface here (bind time).
pub(crate) fn fp_views<'a>(
    plan: &ExecPlan,
    folded: &'a HashMap<String, crate::graph::bn_fold::FoldedParams>,
) -> Result<Vec<FpStepView<'a>>, DfqError> {
    let mut out = Vec::with_capacity(plan.param_names().len());
    for step in &plan.steps {
        let g = match &step.op {
            Op::Conv(c) => &c.g,
            Op::Dense(d) => &d.g,
            Op::Gap(_) => continue,
        };
        let name = &plan.param_names()[g.param];
        let p = folded.get(name).ok_or_else(|| {
            DfqError::data(format!("module '{name}' has no folded parameters"))
        })?;
        if p.w.data.len() != g.kdim * g.cout {
            return Err(DfqError::graph(format!(
                "module '{name}': weight shape {} does not match the plan's \
                 {}x{} GEMM",
                p.w.shape, g.kdim, g.cout
            )));
        }
        if p.b.len() != g.cout {
            return Err(DfqError::graph(format!(
                "module '{name}': {} bias values for {} output channels",
                p.b.len(),
                g.cout
            )));
        }
        debug_assert_eq!(out.len(), g.param);
        out.push(FpStepView { w: &p.w.data, b: &p.b });
    }
    Ok(out)
}

/// The f32 epilogue, in the interpreter's exact op order: `+bias`
/// (per channel), then `+residual`, then ReLU.
fn fp_epilogue(g: &GemmStep, b: &[f32], res: Option<&[f32]>, out: &mut [f32]) {
    for chunk in out.chunks_exact_mut(g.cout) {
        for (o, bias) in chunk.iter_mut().zip(b) {
            *o += *bias;
        }
    }
    if let Some(r) = res {
        for (o, &rv) in out.iter_mut().zip(r) {
            *o += rv;
        }
    }
    if g.relu {
        for o in out.iter_mut() {
            if *o < 0.0 {
                *o = 0.0;
            }
        }
    }
}

impl Domain for FpDomain<'_> {
    type Elem = f32;

    fn conv(
        &self,
        c: &ConvOp,
        n: usize,
        src: &[f32],
        res: Option<&[f32]>,
        out: &mut Vec<f32>,
        patches: &mut Vec<f32>,
        _threads: usize,
    ) -> Result<(), DfqError> {
        let p = &self.params[c.g.param];
        let m = n * c.ho * c.wo;
        if c.g.kernel.elide_im2col {
            // 1x1 stride-1 SAME: the patch matrix equals the input
            // buffer element-for-element, so the GEMM result is
            // bit-identical with the copy skipped
            ops::gemm_f32_into(src, p.w, m, c.g.kdim, c.g.cout, out);
        } else {
            im2col_slice_into(
                src, n, c.in_h, c.in_w, c.cin, c.kh, c.kw, c.stride, Padding::Same, patches,
            );
            ops::gemm_f32_into(&patches[..m * c.g.kdim], p.w, m, c.g.kdim, c.g.cout, out);
        }
        fp_epilogue(&c.g, p.b, res, out);
        Ok(())
    }

    fn dense(
        &self,
        d: &DenseOp,
        n: usize,
        src: &[f32],
        res: Option<&[f32]>,
        out: &mut Vec<f32>,
        _threads: usize,
    ) -> Result<(), DfqError> {
        let p = &self.params[d.g.param];
        ops::gemm_f32_into(src, p.w, n, d.g.kdim, d.g.cout, out);
        fp_epilogue(&d.g, p.b, res, out);
        Ok(())
    }

    fn gap(&self, g: &GapOp, n: usize, src: &[f32], out: &mut [f32]) -> Result<(), DfqError> {
        // sum then scale, in ops::global_avg_pool's exact order
        sum_pool(n, g.h, g.w, g.c, src, out, |a, b| a + b);
        let inv = 1.0 / (g.h * g.w) as f32;
        for v in out.iter_mut() {
            *v *= inv;
        }
        Ok(())
    }
}

/// Shared (N,H,W,C) → (N,C) window sum over a pre-zeroed output.
fn sum_pool<T: Copy>(
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    src: &[T],
    out: &mut [T],
    add: impl Fn(T, T) -> T,
) {
    for b in 0..n {
        for y in 0..h {
            for x in 0..w {
                let base = ((b * h + y) * w + x) * c;
                for ch in 0..c {
                    out[b * c + ch] = add(out[b * c + ch], src[base + ch]);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_take_uninit_keeps_capacity() {
        let mut s: Scratch<i32> = Scratch::new();
        s.recycle(vec![7; 16]);
        // the reused prefix of take_uninit is unspecified (here: stale)
        let v = s.take_uninit(8);
        assert_eq!(v.len(), 8);
        s.recycle(v);
        // take always hands back zeros, even from a dirty recycled buffer
        let v = s.take(8);
        assert_eq!(v, vec![0; 8]);
        s.recycle(v);
        // growth beyond the recycled capacity zero-fills the extension
        let v = s.take_uninit(32);
        assert_eq!(v.len(), 32);
        assert!(v[16..].iter().all(|&x| x == 0));
    }

    #[test]
    fn recycle_ignores_unallocated() {
        let mut s: Scratch<f32> = Scratch::new();
        s.recycle(Vec::new());
        assert_eq!(s.free.len(), 0);
    }
}
