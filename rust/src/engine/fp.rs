//! The FP oracle engine: executes the unified graph on folded weights in
//! f32, supplying the `O` of Eq. 5 (calibration targets) and the FP rows
//! of the paper's tables.
//!
//! [`FpEngine::run`] executes the same compiled [`ExecPlan`] as the
//! integer engine — shape-resolved steps over statically assigned buffer
//! slots — so dead activations are dropped (and their buffers recycled)
//! as their last consumer retires instead of retaining every activation
//! for the whole pass. [`FpEngine::run_acts`] deliberately keeps the
//! retain-everything interpreter: calibration and the fake-quant
//! baselines read every intermediate (and the transform hook must fire
//! per module). The two paths use identical arithmetic order and are
//! bit-identical (`rust/tests/prop_plan.rs`). The plan path also honors
//! the compile-time kernel selection where it applies to f32: a 1×1
//! stride-1 conv's im2col is elided (the patch matrix equals the input
//! buffer element-for-element, so the GEMM is bit-identical with the
//! copy skipped).
//!
//! Malformed graphs (dangling names, missing parameters, shape
//! mismatches) surface as typed [`DfqError`]s — this engine no longer
//! panics on them.

use std::collections::HashMap;

use crate::engine::exec::{self, Scratch};
use crate::engine::plan::ExecPlan;
use crate::error::DfqError;
use crate::graph::bn_fold::FoldedParams;
use crate::graph::{Graph, ModuleKind};
use crate::tensor::im2col::Padding;
use crate::tensor::{ops, Shape, Tensor};

/// Floating-point executor over a unified-module graph.
pub struct FpEngine<'g> {
    graph: &'g Graph,
    folded: &'g HashMap<String, FoldedParams>,
}

impl<'g> FpEngine<'g> {
    /// Build from a graph and its folded parameters.
    pub fn new(graph: &'g Graph, folded: &'g HashMap<String, FoldedParams>) -> Self {
        FpEngine { graph, folded }
    }

    /// Compile the graph into the flat [`ExecPlan`] the run path
    /// executes (all structural validation happens here).
    pub fn plan(&self) -> Result<ExecPlan, DfqError> {
        ExecPlan::compile_fp(self.graph, self.graph.input_hwc)
    }

    /// Run a batch, applying `transform(module_name, act)` to every
    /// module output before it is recorded/consumed downstream. This is
    /// the fake-quantization hook used by the comparison baselines
    /// (`quant::baselines`): simulating a quantizer in f32 while the
    /// dataflow stays exactly the real graph's. Retains every activation
    /// by design (the hook and the calibrator read them all).
    pub fn run_acts_transformed<F>(
        &self,
        x: &Tensor,
        transform: F,
    ) -> Result<HashMap<String, Tensor>, DfqError>
    where
        F: Fn(&str, Tensor) -> Tensor,
    {
        let mut acts: HashMap<String, Tensor> = HashMap::new();
        acts.insert("input".to_string(), transform("input", x.clone()));
        for m in &self.graph.modules {
            let src = acts.get(&m.src).ok_or_else(|| {
                DfqError::graph(format!(
                    "{}: missing input activation '{}'",
                    m.name, m.src
                ))
            })?;
            let mut out = match &m.kind {
                ModuleKind::Conv { cin, stride, .. } => {
                    if src.shape.rank() != 4 || src.shape.dim(3) != *cin {
                        return Err(DfqError::graph(format!(
                            "{}: conv expects an NHWC activation with {cin} \
                             channels, '{}' has shape {}",
                            m.name, m.src, src.shape
                        )));
                    }
                    let p = self.param(&m.name)?;
                    ops::conv2d(src, &p.w, &p.b, *stride, Padding::Same)
                }
                ModuleKind::Dense { .. } => {
                    let p = self.param(&m.name)?;
                    let rows = src.shape.dim(0);
                    let cin = if rows == 0 { 0 } else { src.numel() / rows };
                    if p.w.shape.dim(0) != cin {
                        return Err(DfqError::graph(format!(
                            "{}: dense weight expects {} input features, \
                             activation provides {cin}",
                            m.name,
                            p.w.shape.dim(0)
                        )));
                    }
                    let flat = src.reshape(&[rows, cin]);
                    ops::dense(&flat, &p.w, &p.b)
                }
                ModuleKind::Gap => {
                    if src.shape.rank() != 4 {
                        return Err(DfqError::graph(format!(
                            "{}: global average pool needs an NHWC activation, \
                             '{}' has rank {}",
                            m.name,
                            m.src,
                            src.shape.rank()
                        )));
                    }
                    ops::global_avg_pool(src)
                }
            };
            if let Some(r) = &m.res {
                let rt = acts.get(r).ok_or_else(|| {
                    DfqError::graph(format!(
                        "{}: missing residual activation '{r}'",
                        m.name
                    ))
                })?;
                if rt.shape != out.shape {
                    return Err(DfqError::graph(format!(
                        "{}: residual '{r}' shape {} does not match output \
                         shape {}",
                        m.name, rt.shape, out.shape
                    )));
                }
                out = ops::add(&out, rt);
            }
            if m.relu {
                ops::relu_inplace(&mut out);
            }
            acts.insert(m.name.clone(), transform(&m.name, out));
        }
        Ok(acts)
    }

    fn param(&self, name: &str) -> Result<&FoldedParams, DfqError> {
        self.folded.get(name).ok_or_else(|| {
            DfqError::data(format!("module '{name}' has no folded parameters"))
        })
    }

    /// Run a batch, returning all activations keyed by module name
    /// (plus `"input"`). `x` is NHWC, already normalised.
    pub fn run_acts(&self, x: &Tensor) -> Result<HashMap<String, Tensor>, DfqError> {
        self.run_acts_transformed(x, |_, t| t)
    }

    /// Run a batch, returning only the final output — through the
    /// compiled plan, so dead activations recycle as the pass advances
    /// instead of accumulating in a map.
    pub fn run(&self, x: &Tensor) -> Result<Tensor, DfqError> {
        let plan = self.plan()?;
        self.run_plan(&plan, x, &mut Scratch::new())
    }

    /// Execute a plan previously compiled by [`FpEngine::plan`] — the
    /// compile-once hot path (no name or shape resolution per batch).
    pub fn run_plan(
        &self,
        plan: &ExecPlan,
        x: &Tensor,
        scratch: &mut Scratch<f32>,
    ) -> Result<Tensor, DfqError> {
        plan.check_input(&x.shape)?;
        let views = exec::fp_views(plan, self.folded)?;
        let n = x.shape.dim(0);
        let out = exec::execute(
            plan,
            &exec::FpDomain { params: &views },
            x.data.clone(),
            n,
            scratch,
            1,
        )?;
        Ok(Tensor { shape: Shape(plan.out_dims(n)), data: out })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::UnifiedModule;
    use crate::tensor::Tensor;

    /// identity 1x1 conv + residual + relu, then gap: checks the
    /// epilogue order (bias, residual, relu) matches the python oracle.
    #[test]
    fn epilogue_order_bias_res_relu() {
        let graph = Graph {
            name: "t".into(),
            input_hwc: (2, 2, 1),
            modules: vec![
                UnifiedModule {
                    name: "c".into(),
                    kind: ModuleKind::Conv { kh: 1, kw: 1, cin: 1, cout: 1, stride: 1 },
                    src: "input".into(),
                    res: Some("input".into()),
                    relu: true,
                },
                UnifiedModule {
                    name: "gap".into(),
                    kind: ModuleKind::Gap,
                    src: "c".into(),
                    res: None,
                    relu: false,
                },
            ],
        };
        let mut folded = HashMap::new();
        folded.insert(
            "c".to_string(),
            FoldedParams { w: Tensor::from_vec(&[1, 1, 1, 1], vec![2.0]), b: vec![-1.0] },
        );
        let eng = FpEngine::new(&graph, &folded);
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![1.0, -2.0, 0.5, 0.0]);
        let acts = eng.run_acts(&x).unwrap();
        // c = relu(2x - 1 + x) = relu(3x - 1)
        let want = [2.0f32, 0.0, 0.5, 0.0];
        for (a, b) in acts["c"].data.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6);
        }
        assert_eq!(acts["gap"].shape.dims(), &[1, 1]);
        assert!((acts["gap"].data[0] - 0.625).abs() < 1e-6);
        // the plan path produces bit-identical output
        let via_plan = eng.run(&x).unwrap();
        assert_eq!(via_plan.data, acts["gap"].data);
    }

    #[test]
    fn dense_flattens_gap_output() {
        let graph = Graph {
            name: "t".into(),
            input_hwc: (2, 2, 2),
            modules: vec![
                UnifiedModule {
                    name: "gap".into(),
                    kind: ModuleKind::Gap,
                    src: "input".into(),
                    res: None,
                    relu: false,
                },
                UnifiedModule {
                    name: "fc".into(),
                    kind: ModuleKind::Dense { cin: 2, cout: 3 },
                    src: "gap".into(),
                    res: None,
                    relu: false,
                },
            ],
        };
        let mut folded = HashMap::new();
        folded.insert(
            "fc".to_string(),
            FoldedParams {
                w: Tensor::from_vec(&[2, 3], vec![1., 0., 1., 0., 1., 1.]),
                b: vec![0.0, 0.0, 1.0],
            },
        );
        let eng = FpEngine::new(&graph, &folded);
        let x = Tensor::from_vec(&[1, 2, 2, 2], vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let y = eng.run(&x).unwrap();
        // gap = [4, 5]; fc = [4, 5, 10]
        assert_eq!(y.data, vec![4.0, 5.0, 10.0]);
    }

    #[test]
    fn malformed_graph_is_typed_error_not_panic() {
        // the last non-typed error surface: FpEngine used to panic on a
        // dangling src / missing params
        let graph = Graph {
            name: "t".into(),
            input_hwc: (2, 2, 1),
            modules: vec![UnifiedModule {
                name: "c".into(),
                kind: ModuleKind::Conv { kh: 1, kw: 1, cin: 1, cout: 1, stride: 1 },
                src: "input".into(),
                res: None,
                relu: false,
            }],
        };
        let folded = HashMap::new(); // no params for 'c'
        let eng = FpEngine::new(&graph, &folded);
        let x = Tensor::zeros(&[1, 2, 2, 1]);
        let err = eng.run(&x).unwrap_err();
        assert!(matches!(err, DfqError::Data(_)), "{err}");
        let err = eng.run_acts(&x).unwrap_err();
        assert!(matches!(err, DfqError::Data(_)), "{err}");
    }
}
