//! The FP oracle engine: executes the unified graph on folded weights in
//! f32, recording every module's activation (the `O` of Eq. 5).

use std::collections::HashMap;

use crate::graph::bn_fold::FoldedParams;
use crate::graph::{Graph, ModuleKind};
use crate::tensor::im2col::Padding;
use crate::tensor::{ops, Tensor};

/// Floating-point executor over a unified-module graph.
pub struct FpEngine<'g> {
    graph: &'g Graph,
    folded: &'g HashMap<String, FoldedParams>,
}

impl<'g> FpEngine<'g> {
    /// Build from a graph and its folded parameters.
    pub fn new(graph: &'g Graph, folded: &'g HashMap<String, FoldedParams>) -> Self {
        FpEngine { graph, folded }
    }

    /// Run a batch, applying `transform(module_name, act)` to every
    /// module output before it is recorded/consumed downstream. This is
    /// the fake-quantization hook used by the comparison baselines
    /// (`quant::baselines`): simulating a quantizer in f32 while the
    /// dataflow stays exactly the real graph's.
    pub fn run_acts_transformed<F>(&self, x: &Tensor, transform: F) -> HashMap<String, Tensor>
    where
        F: Fn(&str, Tensor) -> Tensor,
    {
        let mut acts: HashMap<String, Tensor> = HashMap::new();
        acts.insert("input".to_string(), transform("input", x.clone()));
        for m in &self.graph.modules {
            let src = &acts[&m.src];
            let mut out = match &m.kind {
                ModuleKind::Conv { stride, .. } => {
                    let p = &self.folded[&m.name];
                    ops::conv2d(src, &p.w, &p.b, *stride, Padding::Same)
                }
                ModuleKind::Dense { .. } => {
                    let p = &self.folded[&m.name];
                    let flat = src.reshape(&[src.shape.dim(0), src.numel() / src.shape.dim(0)]);
                    ops::dense(&flat, &p.w, &p.b)
                }
                ModuleKind::Gap => ops::global_avg_pool(src),
            };
            if let Some(r) = &m.res {
                out = ops::add(&out, &acts[r]);
            }
            if m.relu {
                ops::relu_inplace(&mut out);
            }
            acts.insert(m.name.clone(), transform(&m.name, out));
        }
        acts
    }

    /// Run a batch, returning all activations keyed by module name
    /// (plus `"input"`). `x` is NHWC, already normalised.
    pub fn run_acts(&self, x: &Tensor) -> HashMap<String, Tensor> {
        self.run_acts_transformed(x, |_, t| t)
    }

    /// Run a batch, returning only the final output.
    pub fn run(&self, x: &Tensor) -> Tensor {
        let mut acts = self.run_acts(x);
        acts.remove(&self.graph.modules.last().unwrap().name).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::UnifiedModule;
    use crate::tensor::Tensor;

    /// identity 1x1 conv + residual + relu, then gap: checks the
    /// epilogue order (bias, residual, relu) matches the python oracle.
    #[test]
    fn epilogue_order_bias_res_relu() {
        let graph = Graph {
            name: "t".into(),
            input_hwc: (2, 2, 1),
            modules: vec![
                UnifiedModule {
                    name: "c".into(),
                    kind: ModuleKind::Conv { kh: 1, kw: 1, cin: 1, cout: 1, stride: 1 },
                    src: "input".into(),
                    res: Some("input".into()),
                    relu: true,
                },
                UnifiedModule {
                    name: "gap".into(),
                    kind: ModuleKind::Gap,
                    src: "c".into(),
                    res: None,
                    relu: false,
                },
            ],
        };
        let mut folded = HashMap::new();
        folded.insert(
            "c".to_string(),
            FoldedParams { w: Tensor::from_vec(&[1, 1, 1, 1], vec![2.0]), b: vec![-1.0] },
        );
        let eng = FpEngine::new(&graph, &folded);
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![1.0, -2.0, 0.5, 0.0]);
        let acts = eng.run_acts(&x);
        // c = relu(2x - 1 + x) = relu(3x - 1)
        let want = [2.0f32, 0.0, 0.5, 0.0];
        for (a, b) in acts["c"].data.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6);
        }
        assert_eq!(acts["gap"].shape.dims(), &[1, 1]);
        assert!((acts["gap"].data[0] - 0.625).abs() < 1e-6);
    }

    #[test]
    fn dense_flattens_gap_output() {
        let graph = Graph {
            name: "t".into(),
            input_hwc: (2, 2, 2),
            modules: vec![
                UnifiedModule {
                    name: "gap".into(),
                    kind: ModuleKind::Gap,
                    src: "input".into(),
                    res: None,
                    relu: false,
                },
                UnifiedModule {
                    name: "fc".into(),
                    kind: ModuleKind::Dense { cin: 2, cout: 3 },
                    src: "gap".into(),
                    res: None,
                    relu: false,
                },
            ],
        };
        let mut folded = HashMap::new();
        folded.insert(
            "fc".to_string(),
            FoldedParams {
                w: Tensor::from_vec(&[2, 3], vec![1., 0., 1., 0., 1., 1.]),
                b: vec![0.0, 0.0, 1.0],
            },
        );
        let eng = FpEngine::new(&graph, &folded);
        let x = Tensor::from_vec(&[1, 2, 2, 2], vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let y = eng.run(&x);
        // gap = [4, 5]; fc = [4, 5, 10]
        assert_eq!(y.data, vec![4.0, 5.0, 10.0]);
    }
}
