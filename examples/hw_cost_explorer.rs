//! Hardware cost explorer: sweeps the RTL cost model (Table 5) across
//! clocks, codebook sizes and bit-widths, and prints the network-level
//! energy breakdown behind the paper's ~4x and 1–2% claims.
//!
//!     cargo run --release --example hw_cost_explorer

use dfq::hw::energy::{estimate, EnergyTable, Precision, RequantStyle};
use dfq::hw::synth::{headline_ratios, synthesize, REF_CLOCK_MHZ};
use dfq::hw::units::RequantOp;
use dfq::models::resnet;
use dfq::report::table::{pct, Table};

fn main() {
    // Table 5 at several clocks
    let mut t = Table::new(
        "Requantization operator cost across clocks",
        &["clock (MHz)", "scaling mW", "codebook mW", "bit-shift mW"],
    );
    for clock in [250.0, 500.0, 1000.0] {
        let sf = synthesize(RequantOp::ScalingFactor { zero_point: false }, clock);
        let cb = synthesize(RequantOp::Codebook { index_bits: 4, entry_bits: 8 }, clock);
        let bs = synthesize(RequantOp::BitShift, clock);
        t.row(vec![
            format!("{clock}"),
            format!("{:.1}", sf.power_mw),
            format!("{:.1}", cb.power_mw),
            format!("{:.1}", bs.power_mw),
        ]);
    }
    println!("{}", t.render());

    // codebook size sweep: the encode/decode cost grows with entries
    let mut t = Table::new(
        "Codebook size sweep (500 MHz)",
        &["index bits", "entries", "power mW", "area um^2"],
    );
    for bits in [2u32, 3, 4, 5, 6] {
        let r = synthesize(RequantOp::Codebook { index_bits: bits, entry_bits: 8 }, REF_CLOCK_MHZ);
        t.row(vec![
            format!("{bits}"),
            format!("{}", 1 << bits),
            format!("{:.1}", r.power_mw),
            format!("{:.1}", r.area_um2),
        ]);
    }
    println!("{}", t.render());

    let (p, a) = headline_ratios();
    println!("headline: codebook/bit-shift power {p:.1}x (paper ~14.8x), area {a:.1}x (paper ~9.0x)\n");

    // network-level energy: FP32 vs int8 with each requant style
    let graph = resnet::resnet_graph("resnet_l", 5, 10);
    let e = EnergyTable::default();
    let mut t = Table::new(
        &format!(
            "Per-inference energy, {} ({} MMACs)",
            graph.name,
            graph.total_macs() / 1_000_000
        ),
        &["precision", "MAC uJ", "requant uJ", "mem uJ", "total uJ", "requant share"],
    );
    let fp = estimate(&graph, Precision::Fp32, &e);
    t.row(vec![
        "FP32".into(),
        format!("{:.2}", fp.mac_uj),
        "-".into(),
        format!("{:.2}", fp.mem_uj),
        format!("{:.2}", fp.total_uj()),
        "-".into(),
    ]);
    for (label, style) in [
        ("int8 + scaling", RequantStyle::ScalingFactor),
        ("int8 + codebook", RequantStyle::Codebook),
        ("int8 + bit-shift", RequantStyle::BitShift),
    ] {
        let c = estimate(&graph, Precision::Int { bits: 8, requant: style }, &e);
        t.row(vec![
            label.into(),
            format!("{:.2}", c.mac_uj),
            format!("{:.3}", c.requant_uj),
            format!("{:.2}", c.mem_uj),
            format!("{:.2}", c.total_uj()),
            pct(c.requant_share()),
        ]);
    }
    println!("{}", t.render());
    let q8 = estimate(
        &graph,
        Precision::Int { bits: 8, requant: RequantStyle::BitShift },
        &e,
    );
    println!(
        "int8 vs FP32: {:.1}x less memory traffic, {:.1}x less energy (paper: ~4x)",
        fp.traffic_bytes as f64 / q8.traffic_bytes as f64,
        fp.total_uj() / q8.total_uj()
    );
}
