//! Serving demo: the batching inference service running the calibrated
//! quantized ResNet-S through the **PJRT-compiled AOT artifact** on the
//! request path — the deployment story end to end, python nowhere in
//! sight. Falls back to the pure-rust integer engine with `int` as the
//! first argument.
//!
//! Requires `make artifacts`.
//!
//!     cargo run --release --example serve_demo [pjrt|int] [n_requests]

use std::sync::Arc;

use dfq::coordinator::serve::{Backend, InferenceService, ServeConfig};
use dfq::data::artifacts::ModelBundle;
use dfq::engine::int::IntEngine;
use dfq::prelude::*;
use dfq::report::experiments;
use dfq::runtime::{ArgValue, PjrtWorker};
use dfq::util::timer::Timer;

struct PjrtBackend {
    worker: PjrtWorker,
    path: std::path::PathBuf,
    tail: Vec<ArgValue>,
    bundle: ModelBundle,
    spec: QuantSpec,
    batch: usize,
}

impl Backend for PjrtBackend {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn run_batch(&self, batch: &Tensor) -> Result<Tensor, String> {
        let eng = IntEngine::new(&self.bundle.graph, &self.bundle.folded, &self.spec);
        let mut argv = vec![ArgValue::I32(eng.quantize_input(batch))];
        argv.extend(self.tail.iter().cloned());
        let out = self.worker.run(&self.path, argv)?;
        Ok(out[0].as_i32()?.map_f32(|v| v as f32))
    }
}

struct IntBackend {
    bundle: ModelBundle,
    spec: QuantSpec,
}

impl Backend for IntBackend {
    fn batch_size(&self) -> usize {
        16
    }

    fn run_batch(&self, batch: &Tensor) -> Result<Tensor, String> {
        let eng = IntEngine::new(&self.bundle.graph, &self.bundle.folded, &self.spec);
        Ok(eng.run(batch).map_f32(|v| v as f32))
    }
}

fn main() {
    let mode = std::env::args().nth(1).unwrap_or_else(|| "pjrt".into());
    let n_req: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(64);
    let model = "resnet_s";
    let art = Artifacts::open("artifacts").expect("run `make artifacts` first");
    let bundle = art.load_model(model).unwrap();
    let calib = art.calibration_images(1).unwrap();
    let out = experiments::calibrate_ours(&bundle, &calib, 8);
    println!("calibrated {model} in {:.2}s; starting {mode} backend", out.seconds);

    let backend: Arc<dyn Backend> = if mode == "pjrt" {
        let worker = PjrtWorker::start().expect("pjrt");
        let path = art.hlo_path(model, "q_logits").unwrap();
        let t = Timer::start();
        worker.warm(&path).expect("compile artifact");
        println!("compiled q_logits artifact in {:.2}s", t.secs());
        let batch = art.artifact_batch(model, "q_logits").unwrap();
        let eng = IntEngine::new(&bundle.graph, &bundle.folded, &out.spec);
        let mut tail = Vec::new();
        for m in bundle.graph.weight_modules() {
            let qp = &eng.qparams()[&m.name];
            tail.push(ArgValue::I32(qp.w.clone()));
            tail.push(ArgValue::I32(dfq::tensor::TensorI32::from_vec(
                &[qp.b.len()],
                qp.b.clone(),
            )));
            tail.push(ArgValue::I32Vec(
                out.spec.shift_vector(&bundle.graph, &m.name).to_vec(),
            ));
        }
        Arc::new(PjrtBackend {
            worker,
            path,
            tail,
            bundle: art.load_model(model).unwrap(),
            spec: out.spec.clone(),
            batch,
        })
    } else {
        Arc::new(IntBackend { bundle: art.load_model(model).unwrap(), spec: out.spec.clone() })
    };

    let ds = art.classification_set("synthimagenet_val").unwrap();
    let svc = Arc::new(InferenceService::start(backend, ServeConfig::default()));
    let t = Timer::start();
    let mut handles = Vec::new();
    for i in 0..n_req {
        let svc = svc.clone();
        let (img, label) = {
            let (x, labels) = ds.batch(i % ds.len(), 1);
            (x, labels[0])
        };
        handles.push(std::thread::spawn(move || {
            let logits = svc.infer(img).unwrap();
            let mut best = 0usize;
            for (j, v) in logits.iter().enumerate() {
                if *v > logits[best] {
                    best = j;
                }
            }
            (best as i32 == label) as usize
        }));
    }
    let correct: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let secs = t.secs();
    let m = svc.metrics();
    println!(
        "served {n_req} requests in {secs:.2}s -> {:.1} req/s, top-1 {:.1}%",
        n_req as f64 / secs,
        100.0 * correct as f64 / n_req as f64
    );
    println!(
        "batches {}, mean occupancy {:.1}, latency p50 {:.1} ms / p99 {:.1} ms",
        m.batches,
        m.mean_occupancy(),
        m.latency_percentile(50.0) * 1e3,
        m.latency_percentile(99.0) * 1e3
    );
}
