//! Serving demo: the batching inference service running the calibrated
//! quantized ResNet-S — the deployment story end to end, python nowhere
//! in sight. The whole wiring is the `Session` pipeline: both the
//! PJRT-compiled AOT artifact and the pure-rust integer engine come out
//! of `calibrated.engine(kind)` as the same unified `Engine`, and every
//! engine is a serving `Backend` via the blanket impl — zero glue.
//!
//! Requires `make artifacts` (and the `pjrt` cargo feature for the
//! `pjrt` mode). The `int` modes run the data-parallel integer engine:
//! `int` is serial, `int:N` shards batches across N workers, `int:auto`
//! sizes to the machine — all bit-identical.
//!
//!     cargo run --release --example serve_demo [pjrt|int|int:N|int:auto|fp] [n_requests]

use std::sync::Arc;

use dfq::coordinator::serve::{InferenceService, ServeConfig};
use dfq::prelude::*;
use dfq::util::timer::Timer;

fn main() {
    let mode = std::env::args().nth(1).unwrap_or_else(|| "pjrt".into());
    let n_req: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(64);
    let kind = EngineKind::parse(&mode).expect("mode must be fp|int|int:N|int:auto|pjrt");
    let model = "resnet_s";

    let art = Artifacts::open("artifacts").expect("run `make artifacts` first");
    let session = Session::from_artifacts(&art, model).expect("open session");
    let calib = art.calibration_images(1).unwrap();
    let calibrated = session
        .calibrate(CalibConfig::default(), &calib)
        .expect("joint calibration");
    println!(
        "calibrated {model} in {:.2}s; starting {kind} backend",
        calibrated.seconds
    );

    // one line from calibrated model to servable backend — works for
    // the integer engine AND the PJRT runtime identically
    let t = Timer::start();
    let engine = calibrated.engine(kind).expect("build engine");
    if kind == EngineKind::Pjrt {
        println!("compiled q_logits artifact in {:.2}s", t.secs());
    }
    let svc = Arc::new(InferenceService::start(engine, ServeConfig::default()));

    let ds = art.classification_set("synthimagenet_val").unwrap();
    let t = Timer::start();
    let mut handles = Vec::new();
    for i in 0..n_req {
        let svc = svc.clone();
        let (img, label) = {
            let (x, labels) = ds.batch(i % ds.len(), 1);
            (x, labels[0])
        };
        handles.push(std::thread::spawn(move || {
            let logits = svc.infer(img).unwrap();
            let mut best = 0usize;
            for (j, v) in logits.iter().enumerate() {
                if *v > logits[best] {
                    best = j;
                }
            }
            (best as i32 == label) as usize
        }));
    }
    let correct: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let secs = t.secs();
    let m = svc.metrics();
    println!(
        "served {n_req} requests in {secs:.2}s -> {:.1} req/s, top-1 {:.1}%",
        n_req as f64 / secs,
        100.0 * correct as f64 / n_req as f64
    );
    println!(
        "batches {}, mean occupancy {:.1}, latency p50 {:.1} ms / p99 {:.1} ms",
        m.batches,
        m.mean_occupancy(),
        m.latency_percentile(50.0) * 1e3,
        m.latency_percentile(99.0) * 1e3
    );
}
