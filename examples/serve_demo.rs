//! Serving demo: the multi-model `ModelServer` running two calibrated
//! quantized ResNets side by side — the deployment story end to end,
//! python nowhere in sight. The whole wiring is the `Session` pipeline:
//! every engine out of `calibrated.engine(kind)` registers as a named
//! endpoint (each endpoint a 2-replica pool, least-loaded routing) with
//! zero glue, a cloneable `Client` routes requests by model name, and
//! mid-traffic the demo **re-calibrates** resnet_s to 4 bits and rolls
//! it out the production way: a 10% canary arm, a ramp to 50% and then
//! 100%, and finally an atomic hot-swap — zero downtime, zero dropped
//! requests, and every post-cutover answer is bit-exact against the
//! new engine.
//!
//! Requires `make artifacts` (and the `pjrt` cargo feature for the
//! `pjrt` mode). The `int` modes run the data-parallel integer engine:
//! `int` is serial, `int:N` shards batches across N workers, `int:auto`
//! sizes to the machine — all bit-identical.
//!
//!     cargo run --release --example serve_demo [pjrt|int|int:N|int:auto|fp] [n_requests]

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use dfq::prelude::*;
use dfq::util::timer::Timer;

fn main() {
    let mode = std::env::args().nth(1).unwrap_or_else(|| "int:auto".into());
    let n_req: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(128);
    let kind = EngineKind::parse(&mode).expect("mode must be fp|int|int:N|int:auto|pjrt");
    let models = ["resnet_s", "resnet_m"];

    let art = Artifacts::open("artifacts").expect("run `make artifacts` first");
    let calib = art.calibration_images(1).unwrap();

    // registry: one named endpoint per model, same Session pipeline for
    // each — session -> calibrate -> engine -> register. Two replicas
    // per endpoint: two batch collectors, least-loaded routing, results
    // bit-exact regardless of which replica answers.
    let server = ModelServer::new(ServeConfig { replicas: 2, ..Default::default() });
    let mut sessions = Vec::new();
    for model in models {
        let session = Session::from_artifacts(&art, model).expect("open session");
        let calibrated = session
            .calibrate(CalibConfig::default(), &calib)
            .expect("joint calibration");
        println!("calibrated {model} in {:.2}s", calibrated.seconds);
        let t = Timer::start();
        calibrated
            .deploy_into(&server, model, kind)
            .expect("build + register engine");
        if kind == EngineKind::Pjrt {
            println!("compiled {model} q_logits artifact in {:.2}s", t.secs());
        }
        sessions.push(session);
    }
    println!("serving {:?} behind one server, routed by name", server.models());

    // route: interleaved traffic to both models from concurrent clients
    let ds = art.classification_set("synthimagenet_val").unwrap();
    let swapped = Arc::new(AtomicBool::new(false));
    let t = Timer::start();
    let mut handles = Vec::new();
    for i in 0..n_req {
        let client = server.client();
        let model = models[i % models.len()];
        let swapped = swapped.clone();
        let (img, label) = {
            let (x, labels) = ds.batch(i % ds.len(), 1);
            (x, labels[0])
        };
        handles.push(std::thread::spawn(move || {
            let after_swap = swapped.load(Ordering::SeqCst);
            let logits = match client.infer(model, img) {
                Ok(logits) => logits,
                // large n_requests can saturate the admission queue:
                // that is backpressure working, not a demo failure
                Err(DfqError::Overloaded { .. }) => return (0, model, after_swap, None),
                Err(e) => panic!("serve failed: {e}"),
            };
            let mut best = 0usize;
            for (j, v) in logits.iter().enumerate() {
                if *v > logits[best] {
                    best = j;
                }
            }
            ((best as i32 == label) as usize, model, after_swap, Some(logits))
        }));
    }

    // rollout: mid-traffic, re-calibrate resnet_s down to 4 bits and
    // take it live the production way — a 10% canary arm, a ramp to
    // 50% then 100%, then the atomic swap that retires the 8-bit
    // engine. In-flight batches on the old engine drain at every step;
    // nothing is dropped.
    std::thread::sleep(std::time::Duration::from_millis(10));
    let recal = sessions[0]
        .calibrate(CalibConfig { n_bits: 4, ..Default::default() }, &calib)
        .expect("re-calibration");
    let t_swap = Timer::start();
    let new_engine = recal
        .deploy_arm_into(&server, "resnet_s", "canary", 0.1, kind)
        .expect("canary deploy");
    server.ramp("resnet_s", "canary", 0.5).expect("ramp to 50%");
    server.ramp("resnet_s", "canary", 1.0).expect("ramp to 100%");
    server.swap("resnet_s", new_engine.clone()).expect("hot-swap");
    swapped.store(true, Ordering::SeqCst);
    println!(
        "canaried, ramped and swapped resnet_s to a 4-bit spec in {:.1} ms",
        t_swap.millis()
    );

    let mut correct = 0usize;
    let mut shed = 0usize;
    let mut post_swap_checked = 0usize;
    let mut results = Vec::with_capacity(n_req);
    for h in handles {
        results.push(h.join().unwrap());
    }
    // snapshot serving time before the (serial) verification re-runs
    let secs = t.secs();
    for (i, (ok, model, after_swap, logits)) in results.into_iter().enumerate() {
        correct += ok;
        let Some(logits) = logits else {
            shed += 1;
            continue;
        };
        // every request admitted after the swap returned must be served
        // by the new engine, bit-exactly
        if after_swap && model == "resnet_s" {
            let (x, _) = ds.batch(i % ds.len(), 1);
            let want = new_engine.run(&x).unwrap();
            assert_eq!(logits, want.data, "post-swap output is not the new engine's");
            post_swap_checked += 1;
        }
    }
    let served = n_req - shed;
    // fast engines can drain every request while the re-calibration is
    // still running, leaving the mid-traffic check vacuous — so always
    // verify the cutover with a few dedicated post-swap requests too
    let client = server.client();
    for i in 0..4 {
        let (x, _) = ds.batch(i, 1);
        let logits = client.infer("resnet_s", x.clone()).unwrap();
        let want = new_engine.run(&x).unwrap();
        assert_eq!(logits, want.data, "post-swap output is not the new engine's");
        post_swap_checked += 1;
    }
    println!(
        "served {served} requests in {secs:.2}s -> {:.1} req/s, top-1 {:.1}%, \
         {shed} shed by admission control, \
         {post_swap_checked} post-swap responses verified bit-exact vs the 4-bit engine",
        served as f64 / secs,
        100.0 * correct as f64 / served.max(1) as f64
    );
    for arm in server.snapshot("resnet_s").expect("snapshot") {
        println!(
            "  resnet_s arm '{}' @ {:.2}: {} completed across {} replica(s)",
            arm.arm,
            arm.weight,
            arm.metrics.completed,
            arm.replicas.len()
        );
    }
    for (name, m) in server.shutdown() {
        println!(
            "  {name}: {} completed / {} rejected / {} failed, {} swaps, {} batches \
             (mean occupancy {:.1}), latency p50 {:.1} ms / p99 {:.1} ms",
            m.completed,
            m.rejected,
            m.failed,
            m.swaps,
            m.batches,
            m.mean_occupancy(),
            m.latency_percentile(50.0) * 1e3,
            m.latency_percentile(99.0) * 1e3
        );
    }
}
