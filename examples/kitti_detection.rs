//! Table-4 pipeline: the trained DetNet detector on SynthKITTI at
//! FP / 8 / 7 / 6-bit precision, reporting per-class AP. Expect the
//! paper's shape: 8-bit ≈ FP, 7-bit slightly down, 6-bit collapse.
//!
//! Requires `make artifacts`.
//!
//!     cargo run --release --example kitti_detection [eval_n]

use dfq::prelude::*;
use dfq::report::experiments::{self, EvalOptions};

fn main() {
    let eval_n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let art = Artifacts::open("artifacts").expect("run `make artifacts` first");
    let opt = EvalOptions { eval_n, batch: 25, calib_n: 1 };

    println!("== Table 4: detection AP vs precision (eval_n = {eval_n}) ==\n");
    let t = experiments::table4(&art, opt).expect("table4");
    println!("{}", t.render());
    println!("Paper shape check: 8-bit ~ FP, 7-bit competitive, 6-bit dramatic drop.");
}
