//! Quickstart: the whole pipeline through the unified `Session` API on
//! a small model built in-process — no artifacts needed. Builds a
//! ResNet-S *layer* graph with random "trained" weights, lets the
//! session run the dataflow analysis + BN folding, joint-calibrates with
//! Algorithm 1 on one image, and compares the FP and integer-only
//! engines.
//!
//!     cargo run --release --example quickstart

use std::collections::HashMap;

use dfq::graph::layers::LayerOp;
use dfq::models::resnet;
use dfq::prelude::*;
use dfq::util::mathutil::mse;

fn main() {
    // 1. the model, in the fine-grained form a framework would export,
    //    with random He-init parameters standing in for a trained model
    //    (plain `{name}/w` + `{name}/b` keys — the raw export contract)
    let layers = resnet::resnet_layers("resnet_s", 1, 10);
    let mut rng = Pcg::new(7);
    let mut params: HashMap<String, Tensor> = HashMap::new();
    for l in &layers.layers {
        let (shape, fan_in): (Vec<usize>, usize) = match &l.op {
            LayerOp::Conv { kh, kw, cin, cout, .. } => {
                (vec![*kh, *kw, *cin, *cout], kh * kw * cin)
            }
            LayerOp::Dense { cin, cout } => (vec![*cin, *cout], *cin),
            _ => continue,
        };
        let std = (2.0 / fan_in as f32).sqrt();
        let n: usize = shape.iter().product();
        let cout = *shape.last().unwrap();
        params.insert(
            format!("{}/w", l.name),
            Tensor::from_vec(&shape, (0..n).map(|_| rng.normal_ms(0.0, std)).collect()),
        );
        params.insert(
            format!("{}/b", l.name),
            Tensor::from_vec(&[cout], (0..cout).map(|_| rng.normal_ms(0.0, 0.05)).collect()),
        );
    }

    // 2. one Session call runs dataflow fusion + BN folding internally
    let session = Session::from_layers(&layers, &params).expect("build session");
    println!("== dataflow restructuring (paper Fig. 1) ==");
    println!("{}\n", session.fusion_report().expect("built from layers"));

    // 3. one calibration image (paper §2.1) + Algorithm 1 per module
    let calib = dfq::data::dataset::synth_images(1, 32, 3, 42);
    let calibrated = session
        .calibrate(CalibConfig::default(), &calib)
        .expect("joint calibration");
    println!("== joint calibration (Algorithm 1, tau=4, 1 image) ==");
    println!(
        "calibrated {} modules in {:.2}s",
        calibrated.spec().modules.len(),
        calibrated.seconds
    );
    let (lo, med, hi) = calibrated.stats.shift_summary();
    println!("deployed shift range [{lo}, {hi}], median {med} (paper Fig 2b: [1, 10])\n");

    // 4. FP oracle vs the integer-only engine on fresh images — both
    //    are the same unified `Engine` surface
    let x = dfq::data::dataset::synth_images(4, 32, 3, 43);
    let fp_logits = session.fp_engine().run(&x).expect("fp engine");
    // threads: 0 shards batches across all cores (bit-identical to serial)
    let int_engine = calibrated
        .engine(EngineKind::Int { threads: 0 })
        .expect("int engine");
    let q_logits = int_engine.run(&x).expect("int engine run");
    println!("== FP vs integer-only inference ==");
    println!("logit MSE: {:.6}", mse(&q_logits.data, &fp_logits.data));
    for i in 0..4 {
        let row = |t: &Tensor| {
            let c = t.shape.dim(1);
            let r = &t.data[i * c..(i + 1) * c];
            let mut best = 0;
            for (j, v) in r.iter().enumerate() {
                if *v > r[best] {
                    best = j;
                }
            }
            best
        };
        println!(
            "image {i}: FP argmax = {}, int8 argmax = {}",
            row(&fp_logits),
            row(&q_logits)
        );
    }
    println!("\nquickstart OK — see examples/imagenet_resnet.rs for the full pipeline");
}
