//! Quickstart: the whole pipeline on a small model built in-process —
//! no artifacts needed. Builds a ResNet-S graph with random "trained"
//! weights, runs the dataflow analysis, joint-calibrates with Algorithm
//! 1 on one image, and compares FP vs integer-only outputs.
//!
//!     cargo run --release --example quickstart

use std::collections::HashMap;

use dfq::engine::fp::FpEngine;
use dfq::engine::int::IntEngine;
use dfq::graph::bn_fold::FoldedParams;
use dfq::graph::fuse;
use dfq::graph::ModuleKind;
use dfq::models::resnet;
use dfq::prelude::*;
use dfq::quant::joint::{CalibConfig, JointCalibrator};
use dfq::util::mathutil::mse;

fn main() {
    // 1. the model, in the fine-grained form a framework would export
    let layers = resnet::resnet_layers("resnet_s", 1, 10);
    let fused = fuse::fuse(&layers).expect("dataflow analysis");
    println!("== dataflow restructuring (paper Fig. 1) ==");
    println!("{}\n", fuse::quant_point_report(&fused));
    let graph = fused.graph;

    // 2. random He-init weights standing in for a trained model
    let mut rng = Pcg::new(7);
    let mut folded: HashMap<String, FoldedParams> = HashMap::new();
    for m in graph.weight_modules() {
        let (shape, fan_in): (Vec<usize>, usize) = match &m.kind {
            ModuleKind::Conv { kh, kw, cin, cout, .. } => {
                (vec![*kh, *kw, *cin, *cout], kh * kw * cin)
            }
            ModuleKind::Dense { cin, cout } => (vec![*cin, *cout], *cin),
            ModuleKind::Gap => unreachable!(),
        };
        let std = (2.0 / fan_in as f32).sqrt();
        let n: usize = shape.iter().product();
        let cout = *shape.last().unwrap();
        folded.insert(
            m.name.clone(),
            FoldedParams {
                w: Tensor::from_vec(&shape, (0..n).map(|_| rng.normal_ms(0.0, std)).collect()),
                b: (0..cout).map(|_| rng.normal_ms(0.0, 0.05)).collect(),
            },
        );
    }

    // 3. one calibration image (paper §2.1) + Algorithm 1 per module
    let calib = dfq::data::dataset::synth_images(1, 32, 3, 42);
    let out = JointCalibrator::new(CalibConfig::default()).calibrate(&graph, &folded, &calib);
    println!("== joint calibration (Algorithm 1, tau=4, 1 image) ==");
    println!("calibrated {} modules in {:.2}s", out.spec.modules.len(), out.seconds);
    let (lo, med, hi) = out.stats.shift_summary();
    println!("deployed shift range [{lo}, {hi}], median {med} (paper Fig 2b: [1, 10])\n");

    // 4. FP oracle vs the integer-only engine on fresh images
    let x = dfq::data::dataset::synth_images(4, 32, 3, 43);
    let fp_logits = FpEngine::new(&graph, &folded).run(&x);
    let eng = IntEngine::new(&graph, &folded, &out.spec);
    let q_logits = eng.run_dequant(&x);
    println!("== FP vs integer-only inference ==");
    println!("logit MSE: {:.6}", mse(&q_logits.data, &fp_logits.data));
    for i in 0..4 {
        let row = |t: &Tensor| {
            let c = t.shape.dim(1);
            let r = &t.data[i * c..(i + 1) * c];
            let mut best = 0;
            for (j, v) in r.iter().enumerate() {
                if *v > r[best] {
                    best = j;
                }
            }
            best
        };
        println!(
            "image {i}: FP argmax = {}, int8 argmax = {}",
            row(&fp_logits),
            row(&q_logits)
        );
    }
    println!("\nquickstart OK — see examples/imagenet_resnet.rs for the full pipeline");
}
