//! **The headline end-to-end driver** (EXPERIMENTS.md §E2E): the full
//! Table-1 pipeline on real trained models, through the unified
//! `Session` API —
//!
//!   `Session::from_artifacts` (load + fold BN) → `calibrate` on ONE
//!   image (Algorithm 1) → `engine(EngineKind::{Fp, Int})` → top-1 on
//!   the SynthImageNet validation split, alongside both scaling-factor
//!   baselines — plus the calibration-cost table and the dataflow
//!   ablation from the experiment drivers.
//!
//! Requires `make artifacts`.
//!
//!     cargo run --release --example imagenet_resnet [eval_n]

use dfq::prelude::*;
use dfq::quant::baselines::{kl::KlQuant, minmax::MinMaxQuant};
use dfq::report::experiments::{self, EvalOptions};
use dfq::report::table::{pct, Table};

fn main() {
    let eval_n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1000);
    let art = Artifacts::open("artifacts").expect("run `make artifacts` first");
    let opt = EvalOptions { eval_n, ..Default::default() };
    let ds = art.classification_set("synthimagenet_val").expect("dataset");
    let calib = art.calibration_images(1).expect("calibration image");

    println!("== Table 1 pipeline through Session (eval_n = {eval_n}) ==\n");
    let mut table = Table::new(
        "Table 1: ResNet on SynthImageNet — FP vs 8-bit methods (top-1, Session API)",
        &["Model", "FP", "TensorRT-like(KL)", "IOA-like(minmax)", "Ours(bit-shift)", "calib (s)"],
    );
    for name in ["resnet_s", "resnet_m", "resnet_l"] {
        // the canonical pipeline: session -> calibrated -> engines
        let session = Session::from_artifacts(&art, name).expect("open session");
        let calibrated = session
            .calibrate(CalibConfig::default(), &calib)
            .expect("joint calibration");
        let fp = experiments::eval_engine_top1(&*session.fp_engine(), &ds, opt)
            .expect("fp eval");
        let int = calibrated
            .engine(EngineKind::Int { threads: 0 })
            .expect("int engine");
        let q = experiments::eval_engine_top1(&*int, &ds, opt).expect("int eval");
        // the scaling-factor baselines stay on the low-level fake-quant
        // surface (they simulate quantizers in f32, not deployments)
        let bundle = art.load_model(name).expect("bundle for baselines");
        let mut kl = KlQuant::new(8, 8);
        let a_kl = experiments::eval_baseline(&bundle, &mut kl, &calib, &ds, opt)
            .expect("kl baseline");
        let mut mm = MinMaxQuant::new(8, 8);
        let a_mm = experiments::eval_baseline(&bundle, &mut mm, &calib, &ds, opt)
            .expect("minmax baseline");
        table.row(vec![
            name.into(),
            pct(fp),
            pct(a_kl),
            pct(a_mm),
            pct(q),
            format!("{:.2}", calibrated.seconds),
        ]);
    }
    println!("{}", table.render());

    println!("== calibration cost (Table 2) ==\n");
    let t = experiments::table2(&art, opt).expect("table2");
    println!("{}", t.render());

    println!("== dataflow ablation (the paper's hypothesis) ==\n");
    let t = experiments::dataflow_ablation(&art, "resnet_s", opt).expect("ablation");
    println!("{}", t.render());

    println!("Paper shape check: 8-bit drop should be small (paper: ~1.6-1.8pp on ImageNet),");
    println!("and ours should be competitive with the scaling-factor baselines.");
}
