//! **The headline end-to-end driver** (EXPERIMENTS.md §E2E): the full
//! Table-1 pipeline on real trained models —
//!
//!   load trained ResNet-S/M/L from artifacts → fold BN → joint-calibrate
//!   on ONE image (Algorithm 1) → deploy on the integer-only engine →
//!   evaluate FP vs 8-bit top-1 on the SynthImageNet validation split,
//!   plus both scaling-factor baselines.
//!
//! Requires `make artifacts`.
//!
//!     cargo run --release --example imagenet_resnet [eval_n]

use dfq::coordinator::pool::Pool;
use dfq::prelude::*;
use dfq::report::experiments::{self, EvalOptions};

fn main() {
    let eval_n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1000);
    let art = Artifacts::open("artifacts").expect("run `make artifacts` first");
    let opt = EvalOptions { eval_n, ..Default::default() };
    let pool = Pool::auto();

    println!("== Table 1 pipeline: FP vs 8-bit (eval_n = {eval_n}) ==\n");
    let t = experiments::table1(&art, &pool, opt).expect("table1");
    println!("{}", t.render());

    println!("== calibration cost (Table 2) ==\n");
    let t = experiments::table2(&art, opt).expect("table2");
    println!("{}", t.render());

    println!("== dataflow ablation (the paper's hypothesis) ==\n");
    let t = experiments::dataflow_ablation(&art, "resnet_s", opt).expect("ablation");
    println!("{}", t.render());

    // per-model drop summary
    println!("Paper shape check: 8-bit drop should be small (paper: ~1.6-1.8pp on ImageNet),");
    println!("and ours should be competitive with the scaling-factor baselines.");
}
