//! Per-module timing breakdown of the integer engine on the trained
//! ResNet-S — the profiling tool behind EXPERIMENTS.md §Perf.
//!
//!     cargo run --release --example prof_e2e

// quick manual breakdown of the e2e integer path per module
use std::collections::HashMap;
use dfq::prelude::*;
use dfq::engine::int::IntEngine;
fn main() {
    let art = Artifacts::open("artifacts").unwrap();
    let bundle = art.load_model("resnet_s").unwrap();
    let calib = art.calibration_images(1).unwrap();
    let out = dfq::report::experiments::calibrate_ours(&bundle, &calib, 8)
        .expect("calibration runs");
    let eng = IntEngine::new(&bundle.graph, &bundle.folded, &out.spec);
    let ds = art.classification_set("synthimagenet_val").unwrap();
    let (x, _) = ds.batch(0, 8);
    let xq = eng.quantize_input(&x);
    // warm
    for _ in 0..3 { eng.run_acts(&xq).expect("calibrated model runs"); }
    let mut per: HashMap<String, f64> = HashMap::new();
    for _ in 0..10 {
        let mut acts: HashMap<String, dfq::tensor::TensorI32> = HashMap::new();
        acts.insert("input".to_string(), xq.clone());
        for m in &bundle.graph.modules {
            let t = std::time::Instant::now();
            let o = eng.run_module(m, &acts).expect("calibrated model runs");
            *per.entry(m.name.clone()).or_default() += t.elapsed().as_secs_f64();
            acts.insert(m.name.clone(), o);
        }
    }
    let mut v: Vec<(String, f64)> = per.into_iter().collect();
    v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let total: f64 = v.iter().map(|(_, t)| t).sum();
    println!("total {:.2} ms/iter", total * 100.0);
    for (name, t) in v { println!("{name:<14} {:>8.2} ms ({:.0}%)", t * 100.0, t / total * 100.0); }
}
