"""Synthetic dataset generators (build-time substitutes for ImageNet/KITTI).

The paper evaluates on ImageNet (classification, Tables 1–3) and KITTI
(detection, Table 4). Neither dataset nor the pretrained models are
available in this environment (repro band 0/5), so we generate *seeded
procedural datasets* that exercise the same code paths:

* **SynthImageNet** — 10-class 32x32 RGB textures. Each class is a
  distinct procedural family (oriented stripes, checkers, radial blobs,
  ...) with randomised phase/scale/colour plus additive noise, so a CNN
  must genuinely learn filters; classes are separable but not trivially
  so, which is what makes quantization-induced accuracy drops visible.

* **SynthKITTI** — 64x128 RGB "road scenes": a horizon gradient, a road
  trapezoid, noise, and 1..4 objects of 3 classes mirroring KITTI's
  Car / Pedestrian / Cyclist: cars are wide boxes with wheels,
  pedestrians thin vertical capsules, cyclists a body + wheel blob.
  Labels are (present, class, cx, cy, w, h) in normalised coordinates,
  padded to MAX_OBJECTS per image.

Images are stored as u8; both python and rust normalise identically with
``x = (u8/255 - 0.5) / 0.25`` (see rust/src/data/dataset.rs).
"""

from __future__ import annotations

import numpy as np

NUM_CLASSES = 10
IMG_HW = 32
DET_H, DET_W = 64, 128
MAX_OBJECTS = 8
DET_CLASSES = 3  # car, pedestrian, cyclist


def normalize(u8: np.ndarray) -> np.ndarray:
    """The one true normalisation, mirrored in rust."""
    return (u8.astype(np.float32) / 255.0 - 0.5) / 0.25


# --------------------------------------------------------------------------
# SynthImageNet
# --------------------------------------------------------------------------

def _grid(h: int, w: int):
    y, x = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
    return y.astype(np.float32), x.astype(np.float32)


def _class_pattern(cls: int, rng: np.random.Generator, h: int, w: int) -> np.ndarray:
    """One (h, w) float pattern in [0, 1] for a class id."""
    y, x = _grid(h, w)
    phase = rng.uniform(0, 2 * np.pi)
    scale = rng.uniform(0.8, 1.4)
    if cls == 0:  # horizontal stripes
        p = np.sin(2 * np.pi * y / (6.0 * scale) + phase)
    elif cls == 1:  # vertical stripes
        p = np.sin(2 * np.pi * x / (6.0 * scale) + phase)
    elif cls == 2:  # diagonal stripes
        p = np.sin(2 * np.pi * (x + y) / (8.0 * scale) + phase)
    elif cls == 3:  # checkerboard
        p = np.sign(np.sin(2 * np.pi * x / (8 * scale) + phase)
                    * np.sin(2 * np.pi * y / (8 * scale) + phase))
    elif cls == 4:  # radial rings
        cy, cx = rng.uniform(8, h - 8), rng.uniform(8, w - 8)
        r = np.sqrt((y - cy) ** 2 + (x - cx) ** 2)
        p = np.sin(2 * np.pi * r / (5.0 * scale) + phase)
    elif cls == 5:  # single gaussian blob
        cy, cx = rng.uniform(8, h - 8), rng.uniform(8, w - 8)
        s = 4.0 * scale
        p = 2 * np.exp(-((y - cy) ** 2 + (x - cx) ** 2) / (2 * s * s)) - 1
    elif cls == 6:  # two blobs
        p = np.zeros_like(y)
        for _ in range(2):
            cy, cx = rng.uniform(4, h - 4), rng.uniform(4, w - 4)
            s = 3.0 * scale
            p += np.exp(-((y - cy) ** 2 + (x - cx) ** 2) / (2 * s * s))
        p = 2 * np.clip(p, 0, 1) - 1
    elif cls == 7:  # horizontal gradient bands
        p = np.sign(np.sin(2 * np.pi * (y / (h / 2.0)) + phase)) * (x / w * 2 - 1)
    elif cls == 8:  # cross / plus shape
        cy, cx = rng.uniform(10, h - 10), rng.uniform(10, w - 10)
        t = 2.5 * scale
        p = np.where((np.abs(y - cy) < t) | (np.abs(x - cx) < t), 1.0, -1.0)
    else:  # cls 9: high-frequency speckle with structure
        p = np.sin(2 * np.pi * x / (3.0 * scale) + phase) * np.sin(
            2 * np.pi * y / (3.0 * scale) - phase)
    return (p.astype(np.float32) + 1.0) / 2.0


def gen_classification(n: int, seed: int, noise: float = 0.45):
    """Return (images u8 [n,32,32,3], labels i32 [n]).

    The noise level, random gain/offset jitter and the occluding
    distractor patch are tuned so a trained CNN lands around 85–95%
    top-1 rather than saturating — quantization-induced accuracy drops
    (Tables 1 and 3) are invisible on a saturated task."""
    rng = np.random.default_rng(seed)
    imgs = np.zeros((n, IMG_HW, IMG_HW, 3), dtype=np.uint8)
    labels = rng.integers(0, NUM_CLASSES, size=n).astype(np.int32)
    for i in range(n):
        cls = int(labels[i])
        pat = _class_pattern(cls, rng, IMG_HW, IMG_HW)
        # class-correlated but randomised colouring
        base = rng.uniform(0.2, 0.8, size=3).astype(np.float32)
        tint = np.zeros(3, dtype=np.float32)
        tint[cls % 3] = rng.uniform(0.15, 0.4)
        img = pat[..., None] * (base + tint)[None, None, :]
        # heavy pixel noise + random gain/offset (lighting jitter)
        img = img * rng.uniform(0.6, 1.3) + rng.uniform(-0.15, 0.15)
        img += rng.normal(0, noise, img.shape).astype(np.float32)
        # occluding distractor patch of another class's texture
        if rng.uniform() < 0.5:
            other = int(rng.integers(0, NUM_CLASSES))
            opat = _class_pattern(other, rng, IMG_HW, IMG_HW)
            ph, pw = int(rng.integers(6, 12)), int(rng.integers(6, 12))
            py, px = int(rng.integers(0, IMG_HW - ph)), int(rng.integers(0, IMG_HW - pw))
            img[py:py + ph, px:px + pw] = opat[py:py + ph, px:px + pw, None] \
                * base[None, None, :]
        imgs[i] = np.clip(img * 255.0, 0, 255).astype(np.uint8)
    return imgs, labels


# --------------------------------------------------------------------------
# SynthKITTI
# --------------------------------------------------------------------------

def _draw_rect(img, y0, y1, x0, x1, color):
    h, w, _ = img.shape
    y0, y1 = max(0, int(y0)), min(h, int(y1))
    x0, x1 = max(0, int(x0)), min(w, int(x1))
    if y1 > y0 and x1 > x0:
        img[y0:y1, x0:x1] = color


def _draw_disk(img, cy, cx, r, color):
    h, w, _ = img.shape
    y, x = _grid(h, w)
    mask = (y - cy) ** 2 + (x - cx) ** 2 <= r * r
    img[mask] = color


def _scene_background(rng, h, w):
    y, x = _grid(h, w)
    sky = np.array([0.45, 0.55, 0.75], dtype=np.float32)
    ground = np.array([0.35, 0.32, 0.30], dtype=np.float32)
    t = np.clip((y / h - 0.35) * 3.0, 0, 1)[..., None]
    img = sky[None, None, :] * (1 - t) + ground[None, None, :] * t
    # road trapezoid
    road_mask = (y / h > 0.45) & (np.abs(x - w / 2) < (y / h - 0.2) * w * 0.55)
    img[road_mask] = np.array([0.25, 0.25, 0.27], dtype=np.float32)
    img += rng.normal(0, 0.04, img.shape).astype(np.float32)
    return img


def _place_object(img, cls, rng):
    """Draw one object, return (cx, cy, w, h) in normalised coords."""
    h, w, _ = img.shape
    color = rng.uniform(0.1, 0.95, size=3).astype(np.float32)
    if cls == 0:  # car: wide box + darker wheels
        bw = rng.uniform(14, 30)
        bh = bw * rng.uniform(0.38, 0.55)
        cx = rng.uniform(bw / 2 + 1, w - bw / 2 - 1)
        cy = rng.uniform(h * 0.5, h - bh / 2 - 2)
        _draw_rect(img, cy - bh / 2, cy + bh / 2, cx - bw / 2, cx + bw / 2, color)
        wheel = np.array([0.08, 0.08, 0.08], dtype=np.float32)
        r = max(1.5, bh * 0.22)
        _draw_disk(img, cy + bh / 2, cx - bw * 0.3, r, wheel)
        _draw_disk(img, cy + bh / 2, cx + bw * 0.3, r, wheel)
        bh = bh + r  # include wheels in box
    elif cls == 1:  # pedestrian: thin tall capsule + head
        bh = rng.uniform(12, 22)
        bw = bh * rng.uniform(0.22, 0.34)
        cx = rng.uniform(bw / 2 + 1, w - bw / 2 - 1)
        cy = rng.uniform(h * 0.45, h - bh / 2 - 2)
        _draw_rect(img, cy - bh / 2, cy + bh / 2, cx - bw / 2, cx + bw / 2, color)
        _draw_disk(img, cy - bh / 2, cx, bw * 0.55, color * 0.9 + 0.1)
    else:  # cyclist: body box + big wheel disk below
        bh = rng.uniform(10, 18)
        bw = bh * rng.uniform(0.6, 0.9)
        cx = rng.uniform(bw / 2 + 2, w - bw / 2 - 2)
        cy = rng.uniform(h * 0.5, h - bh - 2)
        _draw_rect(img, cy - bh / 2, cy + bh / 2, cx - bw / 2, cx + bw / 2, color)
        wheel = np.array([0.12, 0.12, 0.12], dtype=np.float32)
        _draw_disk(img, cy + bh * 0.7, cx, bh * 0.45, wheel)
        bh = bh * 1.6
    return cx / w, cy / h, bw / w, bh / h


def gen_detection(n: int, seed: int):
    """Return (images u8 [n,64,128,3], labels f32 [n,MAX_OBJECTS,6]).

    label row = (present, class, cx, cy, w, h), normalised coords."""
    rng = np.random.default_rng(seed)
    imgs = np.zeros((n, DET_H, DET_W, 3), dtype=np.uint8)
    labels = np.zeros((n, MAX_OBJECTS, 6), dtype=np.float32)
    for i in range(n):
        img = _scene_background(rng, DET_H, DET_W)
        k = int(rng.integers(1, 5))
        for j in range(min(k, MAX_OBJECTS)):
            cls = int(rng.integers(0, DET_CLASSES))
            cx, cy, bw, bh = _place_object(img, cls, rng)
            labels[i, j] = (1.0, float(cls), cx, cy, bw, bh)
        imgs[i] = np.clip(img * 255.0, 0, 255).astype(np.uint8)
    return imgs, labels
