"""Pure-jnp oracles for every L1 kernel — the single source of truth for
the quantization semantics shared by the Pallas kernels, the lowered L2
model, and the rust integer engine (rust/src/quant/scheme.rs mirrors
these definitions exactly and the integration tests check bit-equality).

Semantics (paper Eq. 1, 3, 4):

* rounding is **round-half-up**: round(x) = floor(x + 0.5). (jnp.round is
  banker's rounding and f32::round in rust is half-away-from-zero; both
  differ from each other, so we standardise on floor(x+0.5), which has an
  exact integer-shift analogue.)
* ``quantize_int(r, N, bits)`` = clip(round(r * 2^N), qmin, qmax).
* integer requantization by shift s = (N_x + N_w) - N_o uses
  ``shift_round``: for s >= 0, (v + (1 << (s-1))) >> s with an arithmetic
  shift (floor division), which equals floor(v / 2^s + 0.5) exactly; for
  s < 0 it is a left shift.
* ReLU modules clamp the requantized output to the **unsigned** range
  [0, 2^bits - 1] (the paper: "outputs of the ReLU layer is in the range
  [0, 255] if the bit-width is 8-bit"); non-ReLU modules clamp to the
  signed range [-2^(bits-1), 2^(bits-1)-1].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def round_half_up(x):
    return jnp.floor(x + 0.5)


def qrange(n_bits: int, unsigned: bool):
    if unsigned:
        return 0, (1 << n_bits) - 1
    return -(1 << (n_bits - 1)), (1 << (n_bits - 1)) - 1


def quantize_int(r, n_frac, n_bits: int, unsigned: bool = False):
    """Float -> integer code (int32). ``n_frac`` may be a traced scalar."""
    qmin, qmax = qrange(n_bits, unsigned)
    scaled = round_half_up(r * jnp.exp2(jnp.asarray(n_frac, jnp.float32)))
    return jnp.clip(scaled, qmin, qmax).astype(jnp.int32)


def dequantize(r_int, n_frac):
    return r_int.astype(jnp.float32) * jnp.exp2(-jnp.asarray(n_frac, jnp.float32))


def quantize(r, n_frac, n_bits: int, unsigned: bool = False):
    """The paper's Q(r; N, n_bits): float -> quantized float."""
    return dequantize(quantize_int(r, n_frac, n_bits, unsigned), n_frac)


def shift_round(v, s):
    """Round-half-up right shift for s >= 0; left shift for s < 0.

    ``v`` int32, ``s`` scalar int32 (may be traced). Branchless so it can
    take runtime shift inputs in the AOT-lowered modules."""
    v = v.astype(jnp.int32)
    s = jnp.asarray(s, jnp.int32)
    s_pos = jnp.maximum(s, 0)
    s_neg = jnp.maximum(-s, 0)
    half = jnp.where(s_pos > 0, jnp.left_shift(1, jnp.maximum(s_pos - 1, 0)), 0)
    right = jnp.right_shift(v + half, s_pos)  # arithmetic shift == floor div
    left = jnp.left_shift(v, s_neg)
    return jnp.where(s >= 0, right, left)


def align(v, s):
    """Bias/residual alignment into the accumulator domain: left shift for
    s >= 0 (the common case, paper §1.2), rounded right shift otherwise."""
    return shift_round(v, -jnp.asarray(s, jnp.int32))


def requantize(acc, out_shift, n_bits: int, relu: bool):
    """int32 accumulator -> n_bits integer code, the paper's Table-5 op."""
    qmin, qmax = qrange(n_bits, unsigned=relu)
    return jnp.clip(shift_round(acc, out_shift), qmin, qmax).astype(jnp.int32)


# --------------------------------------------------------------------------
# Unified-module oracle (Fig. 1 a-d as one parameterised op)
# --------------------------------------------------------------------------

def conv2d_int(x_int, w_int, stride: int, padding: str = "SAME"):
    """Integer conv, NHWC x HWIO -> NHWC, int32 accumulation."""
    return lax.conv_general_dilated(
        x_int.astype(jnp.int32),
        w_int.astype(jnp.int32),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32,
    )


def qmodule_ref(
    x_int,
    w_int,
    b_int,
    bias_shift,
    out_shift,
    *,
    stride: int = 1,
    n_bits: int = 8,
    relu: bool = False,
    res_int=None,
    res_shift=None,
    padding: str = "SAME",
):
    """The unified module (paper Fig. 1):

      acc  = conv_int32(x, w) + align(b, bias_shift) [+ align(res, res_shift)]
      out  = clip(shift_round(acc, out_shift), range(n_bits, unsigned=relu))

    ``bias_shift`` = (N_x + N_w) - N_b, ``out_shift`` = (N_x + N_w) - N_o,
    ``res_shift`` = (N_x + N_w) - N_r. ReLU-then-requantize equals
    requantize-then-clamp-at-zero for round-half-up shifts, so the fused
    form clamps the requantized value to [0, 2^bits - 1].
    """
    acc = conv2d_int(x_int, w_int, stride, padding)
    acc = acc + align(b_int.astype(jnp.int32), bias_shift)[None, None, None, :]
    if res_int is not None:
        acc = acc + align(res_int.astype(jnp.int32), res_shift)
    return requantize(acc, out_shift, n_bits, relu)


def qgemm_ref(p_int, w_int, b_int, bias_shift, out_shift, *, n_bits=8,
              relu=False, res_int=None, res_shift=None):
    """GEMM form of the unified module: (M,K) x (K,N) + bias(N) [+ res(M,N)].

    This is exactly what the Pallas kernel computes; conv modules reach it
    through im2col (see im2col_nhwc)."""
    acc = jnp.dot(p_int.astype(jnp.int32), w_int.astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    acc = acc + align(b_int.astype(jnp.int32), bias_shift)[None, :]
    if res_int is not None:
        acc = acc + align(res_int.astype(jnp.int32), res_shift)
    return requantize(acc, out_shift, n_bits, relu)


def im2col_nhwc(x, kh: int, kw: int, stride: int, padding: str = "SAME"):
    """(N,H,W,C) -> (N*Ho*Wo, kh*kw*C) patches, (kh, kw, C) minor-to-major
    order chosen to match HWIO weights reshaped to (kh*kw*C, O)."""
    n, h, w, c = x.shape
    if padding == "SAME":
        ho = -(-h // stride)
        wo = -(-w // stride)
        pad_h = max(0, (ho - 1) * stride + kh - h)
        pad_w = max(0, (wo - 1) * stride + kw - w)
        x = jnp.pad(x, ((0, 0), (pad_h // 2, pad_h - pad_h // 2),
                        (pad_w // 2, pad_w - pad_w // 2), (0, 0)))
    elif padding == "VALID":
        ho = (h - kh) // stride + 1
        wo = (w - kw) // stride + 1
    else:
        raise ValueError(padding)
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(x[:, i:i + (ho - 1) * stride + 1:stride,
                          j:j + (wo - 1) * stride + 1:stride, :])
    patches = jnp.concatenate(cols, axis=-1)  # (N, Ho, Wo, kh*kw*C)
    return patches.reshape(n * ho * wo, kh * kw * c), (n, ho, wo)


def global_avg_pool_int(x_int, n_bits: int = 8, unsigned: bool = True):
    """Integer global average pool. The model is designed so H*W is a power
    of two (8x8 = 64), making the mean an exact rounded shift — the same
    trick the paper uses everywhere else."""
    n, h, w, c = x_int.shape
    hw = h * w
    assert hw & (hw - 1) == 0, "spatial size must be a power of two"
    s = hw.bit_length() - 1
    total = jnp.sum(x_int.astype(jnp.int32), axis=(1, 2))
    qmin, qmax = qrange(n_bits, unsigned)
    return jnp.clip(shift_round(total, s), qmin, qmax)
