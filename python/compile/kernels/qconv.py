"""L1 Pallas kernel for the unified quantized module (paper Fig. 1 a-d).

The paper's hot spot is the integer conv + bias-align + (residual-align +)
(ReLU +) rounded-shift requantization, executed as ONE fused unit so the
accumulator never round-trips through memory ("the cost of memory accesses
is reduced dramatically without writing the convolution output back to
memory", §1.2.1). We express the conv as an im2col GEMM so the MAC array —
the ASIC's PE grid in the paper, the MXU on TPU — sees a plain int8xint8
-> int32 matmul.

Kernel signature (GEMM form):
    patches (M, K) int32[int8 codes]   — im2col'd quantized ifmaps
    weights (K, N) int32[int8 codes]   — quantized filters, HWIO-flattened
    bias    (1, N) int32               — quantized biases
    shifts  (3,)   int32               — [bias_shift, out_shift, res_shift]
    residual(M, N) int32, optional     — quantized shortcut codes
    out     (M, N) int32[n-bit codes]

Grid is (M/bm, N/bn) with the full K dimension resident per block: for
every shape in our models K = kh*kw*C <= 576, so an (bm=128, K) x (K,
bn=128) tile plus the int32 accumulator needs ~193 KiB of VMEM at int8 —
comfortably inside a TensorCore's 16 MiB with room for double buffering
(DESIGN.md §Hardware-Adaptation). interpret=True: CPU PJRT cannot run
Mosaic custom-calls; interpret mode lowers to portable HLO.

Shifts arrive as a runtime (3,) vector so a single AOT artifact serves
every calibration candidate the rust coordinator tries.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BM = 128  # M tile (im2col rows = output pixels)
BN = 128  # N tile (output channels); shapes are padded up to these


def _qgemm_kernel(shifts_ref, p_ref, w_ref, b_ref, o_ref, *, n_bits, relu):
    qmin, qmax = ref.qrange(n_bits, unsigned=relu)
    acc = jnp.dot(p_ref[...].astype(jnp.int32), w_ref[...].astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    acc = acc + ref.align(b_ref[...].astype(jnp.int32), shifts_ref[0])
    out = ref.shift_round(acc, shifts_ref[1])
    o_ref[...] = jnp.clip(out, qmin, qmax).astype(jnp.int32)


def _qgemm_res_kernel(shifts_ref, p_ref, w_ref, b_ref, r_ref, o_ref, *,
                      n_bits, relu):
    qmin, qmax = ref.qrange(n_bits, unsigned=relu)
    acc = jnp.dot(p_ref[...].astype(jnp.int32), w_ref[...].astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    acc = acc + ref.align(b_ref[...].astype(jnp.int32), shifts_ref[0])
    acc = acc + ref.align(r_ref[...].astype(jnp.int32), shifts_ref[2])
    out = ref.shift_round(acc, shifts_ref[1])
    o_ref[...] = jnp.clip(out, qmin, qmax).astype(jnp.int32)


def _pad_to(x, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def qgemm_pallas(patches, weights, bias, shifts, *, n_bits: int = 8,
                 relu: bool = False, residual=None):
    """Fused unified-module GEMM. Shapes: patches (M,K), weights (K,N),
    bias (N,), shifts (3,) int32, residual (M,N) or None. Returns (M,N)
    int32 codes. M, N are padded internally to BM/BN tiles."""
    m, k = patches.shape
    k2, n = weights.shape
    assert k == k2, (k, k2)
    p = _pad_to(patches.astype(jnp.int32), 0, BM)
    w = weights.astype(jnp.int32)
    b = _pad_to(bias.astype(jnp.int32).reshape(1, n), 1, BN)
    w = _pad_to(w, 1, BN)
    mp, np_ = p.shape[0], w.shape[1]
    grid = (mp // BM, np_ // BN)
    common = dict(
        grid=grid,
        out_specs=pl.BlockSpec((BM, BN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=True,
    )
    shift_spec = pl.BlockSpec((3,), lambda i, j: (0,))
    p_spec = pl.BlockSpec((BM, k), lambda i, j: (i, 0))
    w_spec = pl.BlockSpec((k, BN), lambda i, j: (0, j))
    b_spec = pl.BlockSpec((1, BN), lambda i, j: (0, j))
    if residual is None:
        out = pl.pallas_call(
            functools.partial(_qgemm_kernel, n_bits=n_bits, relu=relu),
            in_specs=[shift_spec, p_spec, w_spec, b_spec],
            **common,
        )(shifts.astype(jnp.int32), p, w, b)
    else:
        r = _pad_to(_pad_to(residual.astype(jnp.int32), 0, BM), 1, BN)
        r_spec = pl.BlockSpec((BM, BN), lambda i, j: (i, j))
        out = pl.pallas_call(
            functools.partial(_qgemm_res_kernel, n_bits=n_bits, relu=relu),
            in_specs=[shift_spec, p_spec, w_spec, b_spec, r_spec],
            **common,
        )(shifts.astype(jnp.int32), p, w, b, r)
    return out[:m, :n]


def qconv2d_pallas(x_int, w_int, b_int, shifts, *, stride: int = 1,
                   n_bits: int = 8, relu: bool = False, res_int=None,
                   padding: str = "SAME"):
    """Conv form: NHWC codes x HWIO codes -> NHWC codes, via im2col + the
    fused GEMM kernel. ``res_int`` is an NHWC tensor of shortcut codes."""
    kh, kw, c, o = w_int.shape
    patches, (n, ho, wo) = ref.im2col_nhwc(x_int.astype(jnp.int32), kh, kw,
                                           stride, padding)
    wmat = w_int.astype(jnp.int32).reshape(kh * kw * c, o)
    res = None
    if res_int is not None:
        res = res_int.astype(jnp.int32).reshape(n * ho * wo, o)
    out = qgemm_pallas(patches, wmat, b_int, shifts, n_bits=n_bits,
                       relu=relu, residual=res)
    return out.reshape(n, ho, wo, o)
