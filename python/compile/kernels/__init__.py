"""L1 Pallas kernels + pure-jnp oracles for the quantization operators."""
from . import ref, quant, qconv  # noqa: F401
