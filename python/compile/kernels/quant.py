"""L1 Pallas kernels for the elementwise quantization operators.

Two kernels:

* ``quantize_pallas``   — float tensor -> integer codes (paper Eq. 1).
* ``requantize_pallas`` — int32 accumulator -> n-bit codes by a rounded
  arithmetic shift (the paper's Table-5 "bit-shifting" operator).

Both take the shift/fractional-bit as a *runtime* scalar carried in a tiny
int32 array so the AOT-lowered HLO modules accept calibrated values chosen
later by the rust coordinator — one artifact serves every grid candidate.

TPU mapping (§Hardware-Adaptation in DESIGN.md): these are pure VPU
element-wise ops; blocks are sized to whole rows so the HBM->VMEM stream
is contiguous. ``interpret=True`` everywhere — the CPU PJRT client cannot
execute Mosaic custom-calls, and interpret-mode lowers to plain HLO that
both the python tests and the rust runtime execute identically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Elementwise block: one lane-aligned row chunk per grid step.
_BLOCK = 1024


def _quantize_kernel(nf_ref, x_ref, o_ref, *, n_bits: int, unsigned: bool):
    qmin, qmax = ref.qrange(n_bits, unsigned)
    nf = nf_ref[0].astype(jnp.float32)
    scaled = jnp.floor(x_ref[...] * jnp.exp2(nf) + 0.5)
    o_ref[...] = jnp.clip(scaled, qmin, qmax).astype(jnp.int32)


def quantize_pallas(x, n_frac, *, n_bits: int = 8, unsigned: bool = False):
    """Quantize a flat f32 vector to int32 codes. ``n_frac`` is a (1,)
    int32 array (runtime input)."""
    (n,) = x.shape
    assert n % _BLOCK == 0, f"pad to a multiple of {_BLOCK}"
    grid = (n // _BLOCK,)
    return pl.pallas_call(
        functools.partial(_quantize_kernel, n_bits=n_bits, unsigned=unsigned),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),       # n_frac broadcast
            pl.BlockSpec((_BLOCK,), lambda i: (i,)),  # x
        ],
        out_specs=pl.BlockSpec((_BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(n_frac.astype(jnp.int32), x.astype(jnp.float32))


def _requantize_kernel(s_ref, v_ref, o_ref, *, n_bits: int, relu: bool):
    qmin, qmax = ref.qrange(n_bits, unsigned=relu)
    out = ref.shift_round(v_ref[...], s_ref[0])
    o_ref[...] = jnp.clip(out, qmin, qmax).astype(jnp.int32)


def requantize_pallas(v, shift, *, n_bits: int = 8, relu: bool = False):
    """Rounded-shift requantization of a flat int32 vector. ``shift`` is a
    (1,) int32 array; negative values left-shift (paper §1.2)."""
    (n,) = v.shape
    assert n % _BLOCK == 0, f"pad to a multiple of {_BLOCK}"
    grid = (n // _BLOCK,)
    return pl.pallas_call(
        functools.partial(_requantize_kernel, n_bits=n_bits, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((_BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((_BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(shift.astype(jnp.int32), v.astype(jnp.int32))
