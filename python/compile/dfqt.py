"""dfqt — the tensor interchange format between the python build path and
the rust runtime.

A ``.dfqt`` file is a flat, little-endian container of named tensors:

    magic   : 6 bytes  b"DFQT1\\n"
    count   : u32      number of tensors
    tensor* : repeated
        name_len : u16
        name     : utf-8 bytes
        dtype    : u8   (0=f32, 1=i8, 2=i32, 3=u8, 4=i64)
        ndim     : u8
        dims     : u32 * ndim
        nbytes   : u64
        data     : raw little-endian buffer

The rust reader lives in ``rust/src/data/dfqt.rs``; both sides are covered
by round-trip tests (``python/tests/test_dfqt.py`` writes, rust unit tests
read a golden file and vice versa via ``dfq dump``).
"""

from __future__ import annotations

import struct
from typing import Dict

import numpy as np

MAGIC = b"DFQT1\n"

_DTYPE_TO_CODE = {
    np.dtype(np.float32): 0,
    np.dtype(np.int8): 1,
    np.dtype(np.int32): 2,
    np.dtype(np.uint8): 3,
    np.dtype(np.int64): 4,
}
_CODE_TO_DTYPE = {v: k for k, v in _DTYPE_TO_CODE.items()}


def write_dfqt(path: str, tensors: Dict[str, np.ndarray]) -> None:
    """Write a name->array mapping. Insertion order is preserved so the
    rust side can rely on deterministic layout for golden tests."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            # note: np.ascontiguousarray would promote 0-d to 1-d;
            # tobytes() below already emits C order for any layout.
            arr = np.asarray(arr)
            if arr.dtype not in _DTYPE_TO_CODE:
                raise ValueError(f"unsupported dtype {arr.dtype} for {name}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", _DTYPE_TO_CODE[arr.dtype]))
            f.write(struct.pack("<B", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            raw = arr.tobytes()
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


def read_dfqt(path: str) -> Dict[str, np.ndarray]:
    """Read a ``.dfqt`` container back into a dict (insertion-ordered)."""
    out: Dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        magic = f.read(len(MAGIC))
        if magic != MAGIC:
            raise ValueError(f"bad magic in {path}: {magic!r}")
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (name_len,) = struct.unpack("<H", f.read(2))
            name = f.read(name_len).decode("utf-8")
            (code,) = struct.unpack("<B", f.read(1))
            (ndim,) = struct.unpack("<B", f.read(1))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            (nbytes,) = struct.unpack("<Q", f.read(8))
            raw = f.read(nbytes)
            arr = np.frombuffer(raw, dtype=_CODE_TO_DTYPE[code]).reshape(dims)
            out[name] = arr.copy()
    return out
