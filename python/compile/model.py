"""L2 — the JAX models (build-time only).

Two families, mirroring the paper's evaluation:

* **ResNet-S/M/L** — residual CNNs for SynthImageNet standing in for
  ResNet-50/101/152 (Tables 1–3). Basic blocks conv-BN-ReLU / conv-BN +
  shortcut (+ReLU), projection shortcuts on downsampling stages, so all
  four Fig.-1 dataflow cases occur naturally:
    (a) bare conv        — the 1x1 projection shortcuts and the FC head,
    (b) conv + ReLU      — the stem and every block's first conv,
    (c) residual + ReLU  — every block's second conv except the last,
    (d) residual, no ReLU— the final block (feeds global-avg-pool).

* **DetNet** — a single-stage detector on SynthKITTI standing in for
  Faster R-CNN on KITTI (Table 4): conv backbone striding to an 8x16 grid,
  a 1x1 head predicting (objectness, 3 class scores, 4 box params) per
  cell.

The *model spec* — an ordered list of unified modules with explicit
dataflow (who feeds whom, who is a residual source) — is serialised into
``artifacts/manifest.json`` and re-built verbatim by the rust graph layer
(rust/src/models), so both sides agree on names, shapes and quantization
points by construction.

The quantized forward is assembled entirely from the L1 Pallas kernels and
takes weights + shift vectors as *runtime inputs*, so one AOT artifact per
topology serves any calibration outcome.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import qconv, ref

BN_EPS = 1e-5
BN_MOMENTUM = 0.9


# --------------------------------------------------------------------------
# Model specs (shared contract with rust/src/models)
# --------------------------------------------------------------------------

def conv_module(name, kh, kw, cin, cout, stride, relu, src, res=None,
                bn=True):
    return dict(name=name, kind="conv", kh=kh, kw=kw, cin=cin, cout=cout,
                stride=stride, relu=relu, src=src, res=res, bn=bn)


def resnet_spec(n_blocks: int, widths=(16, 32, 64), in_ch: int = 3,
                num_classes: int = 10, image_hw: int = 32) -> dict:
    """Build the ResNet module list. ``n_blocks`` per stage: S=1, M=3, L=5."""
    mods: List[dict] = [conv_module("stem", 3, 3, in_ch, widths[0], 1, True,
                                    "input")]
    prev = "stem"
    cin = widths[0]
    last_stage, last_block = len(widths) - 1, n_blocks - 1
    for s, w in enumerate(widths):
        for b in range(n_blocks):
            stride = 2 if (s > 0 and b == 0) else 1
            base = f"s{s}b{b}"
            shortcut = prev
            if stride != 1 or cin != w:
                mods.append(conv_module(f"{base}/proj", 1, 1, cin, w, stride,
                                        False, prev))      # Fig. 1 (a)
                shortcut = f"{base}/proj"
            mods.append(conv_module(f"{base}/c1", 3, 3, cin, w, stride, True,
                                    prev))                  # Fig. 1 (b)
            final = (s == last_stage and b == last_block)
            mods.append(conv_module(f"{base}/c2", 3, 3, w, w, 1,
                                    not final,              # (c) or (d)
                                    f"{base}/c1", res=shortcut))
            prev, cin = f"{base}/c2", w
    mods.append(dict(name="gap", kind="gap", src=prev, cin=cin))
    mods.append(dict(name="fc", kind="dense", cin=cin, cout=num_classes,
                     relu=False, src="gap", bn=False))      # Fig. 1 (a)
    return dict(arch="resnet", n_blocks=n_blocks, widths=list(widths),
                input=dict(h=image_hw, w=image_hw, c=in_ch),
                num_classes=num_classes, modules=mods)


def detnet_spec(in_h: int = 64, in_w: int = 128, n_classes: int = 3) -> dict:
    """Single-stage detector: stride-8 backbone + 1x1 prediction head.
    Head channels = 1 obj + n_classes + 4 box."""
    chans = [(16, 1), (32, 2), (32, 1), (64, 2), (64, 1), (96, 2)]
    mods: List[dict] = []
    prev, cin = "input", 3
    for i, (c, s) in enumerate(chans):
        name = f"bb{i}"
        mods.append(conv_module(name, 3, 3, cin, c, s, True, prev))
        prev, cin = name, c
    head_c = 1 + n_classes + 4
    mods.append(conv_module("head", 1, 1, cin, head_c, 1, False, prev,
                            bn=False))                      # Fig. 1 (a)
    return dict(arch="detnet", input=dict(h=in_h, w=in_w, c=3),
                n_classes=n_classes, grid=dict(h=in_h // 8, w=in_w // 8),
                modules=mods)


RESNET_DEPTHS = {"s": 1, "m": 3, "l": 5}


def model_spec(name: str) -> dict:
    if name.startswith("resnet_"):
        return resnet_spec(RESNET_DEPTHS[name.split("_")[1]])
    if name == "detnet":
        return detnet_spec()
    raise ValueError(name)


def conv_layer_count(spec: dict) -> int:
    return sum(1 for m in spec["modules"] if m["kind"] in ("conv", "dense"))


# --------------------------------------------------------------------------
# Parameter init + FP forward (training / oracle)
# --------------------------------------------------------------------------

def init_params(spec: dict, seed: int) -> Dict[str, np.ndarray]:
    """He-init conv weights; BN gamma=1, beta=0; zero biases."""
    rng = np.random.default_rng(seed)
    params: Dict[str, np.ndarray] = {}
    for m in spec["modules"]:
        if m["kind"] == "conv":
            fan_in = m["kh"] * m["kw"] * m["cin"]
            params[f"{m['name']}/w"] = rng.normal(
                0, np.sqrt(2.0 / fan_in),
                (m["kh"], m["kw"], m["cin"], m["cout"])).astype(np.float32)
            if m.get("bn", True):
                for k, v in (("gamma", 1.0), ("beta", 0.0), ("mean", 0.0),
                             ("var", 1.0)):
                    params[f"{m['name']}/bn/{k}"] = np.full(
                        m["cout"], v, np.float32)
            else:
                params[f"{m['name']}/b"] = np.zeros(m["cout"], np.float32)
        elif m["kind"] == "dense":
            fan_in = m["cin"]
            params[f"{m['name']}/w"] = rng.normal(
                0, np.sqrt(2.0 / fan_in),
                (m["cin"], m["cout"])).astype(np.float32)
            params[f"{m['name']}/b"] = np.zeros(m["cout"], np.float32)
    return params


def split_trainable(params):
    """BN running stats are state, not trainable parameters."""
    train = {k: v for k, v in params.items()
             if not (k.endswith("/bn/mean") or k.endswith("/bn/var"))}
    state = {k: v for k, v in params.items()
             if k.endswith("/bn/mean") or k.endswith("/bn/var")}
    return train, state


def _conv(x, w, stride):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def fp_forward(spec: dict, params: Dict, x, train: bool = False):
    """FP forward pass. In train mode BN uses batch stats and the function
    also returns updated running stats; in eval mode it uses running stats
    (mathematically identical to the BN-folded integer graph's FP oracle).
    Returns (output, new_state, activations) — activations keyed by module
    name (post-ReLU / post-add), used by tests and exported golden data."""
    acts = {"input": x}
    new_state = {}
    for m in spec["modules"]:
        if m["kind"] == "conv":
            h = _conv(acts[m["src"]], params[f"{m['name']}/w"], m["stride"])
            if m.get("bn", True):
                g = params[f"{m['name']}/bn/gamma"]
                beta = params[f"{m['name']}/bn/beta"]
                if train:
                    mu = jnp.mean(h, axis=(0, 1, 2))
                    var = jnp.var(h, axis=(0, 1, 2))
                    new_state[f"{m['name']}/bn/mean"] = (
                        BN_MOMENTUM * params[f"{m['name']}/bn/mean"]
                        + (1 - BN_MOMENTUM) * mu)
                    new_state[f"{m['name']}/bn/var"] = (
                        BN_MOMENTUM * params[f"{m['name']}/bn/var"]
                        + (1 - BN_MOMENTUM) * var)
                else:
                    mu = params[f"{m['name']}/bn/mean"]
                    var = params[f"{m['name']}/bn/var"]
                h = g * (h - mu) / jnp.sqrt(var + BN_EPS) + beta
            else:
                h = h + params[f"{m['name']}/b"]
            if m.get("res"):
                h = h + acts[m["res"]]
            if m["relu"]:
                h = jnp.maximum(h, 0.0)
            acts[m["name"]] = h
        elif m["kind"] == "gap":
            acts[m["name"]] = jnp.mean(acts[m["src"]], axis=(1, 2))
        elif m["kind"] == "dense":
            acts[m["name"]] = (acts[m["src"]] @ params[f"{m['name']}/w"]
                               + params[f"{m['name']}/b"])
    out = acts[spec["modules"][-1]["name"]]
    return out, new_state, acts


def fold_bn(spec: dict, params: Dict) -> Dict[str, np.ndarray]:
    """Fold BN into conv weights/biases (paper §1.2.1: "the batch
    normalization layer is merged into the weights and biases"). Returns
    {name/w, name/b} for every conv/dense module. Mirrored by
    rust/src/graph/bn_fold.rs; test_model.py checks equivalence."""
    out: Dict[str, np.ndarray] = {}
    for m in spec["modules"]:
        if m["kind"] == "conv":
            w = np.asarray(params[f"{m['name']}/w"])
            if m.get("bn", True):
                g = np.asarray(params[f"{m['name']}/bn/gamma"])
                beta = np.asarray(params[f"{m['name']}/bn/beta"])
                mu = np.asarray(params[f"{m['name']}/bn/mean"])
                var = np.asarray(params[f"{m['name']}/bn/var"])
                scale = g / np.sqrt(var + BN_EPS)
                out[f"{m['name']}/w"] = (w * scale[None, None, None, :]
                                         ).astype(np.float32)
                out[f"{m['name']}/b"] = (beta - mu * scale).astype(np.float32)
            else:
                out[f"{m['name']}/w"] = w.astype(np.float32)
                out[f"{m['name']}/b"] = np.asarray(
                    params[f"{m['name']}/b"], np.float32)
        elif m["kind"] == "dense":
            out[f"{m['name']}/w"] = np.asarray(params[f"{m['name']}/w"],
                                               np.float32)
            out[f"{m['name']}/b"] = np.asarray(params[f"{m['name']}/b"],
                                               np.float32)
    return out


def fp_forward_folded(spec: dict, x, folded: Dict[str, jnp.ndarray]):
    """FP forward over BN-folded weights (conv + bias [+ res] [+ relu]).
    This is the per-module oracle O of Eq. 5 — returns (final_out, acts)
    with one activation per unified module, in q_modules order. AOT-
    exported (batch 1) so the rust calibrator can fetch all targets with a
    single PJRT call."""
    acts = {"input": x}
    for m in spec["modules"]:
        name = m["name"]
        if m["kind"] == "conv":
            h = _conv(acts[m["src"]], folded[f"{name}/w"], m["stride"])
            h = h + folded[f"{name}/b"]
            if m.get("res"):
                h = h + acts[m["res"]]
            if m["relu"]:
                h = jnp.maximum(h, 0.0)
            acts[name] = h
        elif m["kind"] == "gap":
            acts[name] = jnp.mean(acts[m["src"]], axis=(1, 2))
        elif m["kind"] == "dense":
            acts[name] = acts[m["src"]] @ folded[f"{name}/w"] \
                + folded[f"{name}/b"]
    return acts[spec["modules"][-1]["name"]], acts


def fp_forward_flat(spec: dict, with_acts: bool):
    """Flat-argument folded forward for AOT lowering: [x, then per module
    (w, b)]. ``with_acts`` selects the all-activations variant."""
    mods = q_modules(spec)

    def fn(x, *flat):
        folded = {}
        it = iter(flat)
        for m in mods:
            folded[f"{m['name']}/w"] = next(it)
            folded[f"{m['name']}/b"] = next(it)
        out, acts = fp_forward_folded(spec, x, folded)
        if with_acts:
            return tuple(acts[m["name"]] for m in mods)
        return (out,)

    names = ["x"]
    for m in mods:
        names += [f"{m['name']}/w", f"{m['name']}/b"]
    return fn, names


# --------------------------------------------------------------------------
# Quantized forward (assembled from L1 kernels; AOT-exported)
# --------------------------------------------------------------------------

def q_modules(spec: dict) -> List[dict]:
    """Modules that carry quantized parameters, in execution order."""
    return [m for m in spec["modules"] if m["kind"] in ("conv", "dense")]


def q_forward(spec: dict, x_int, weights: Dict[str, jnp.ndarray],
              shifts: Dict[str, jnp.ndarray], n_bits: int = 8):
    """Integer-only forward. ``weights`` holds int32 codes ``name/w`` /
    ``name/b``; ``shifts`` holds a (3,) int32 vector per module
    [bias_shift, out_shift, res_shift]. Built from the Pallas kernels, so
    the whole graph lowers into one HLO module with no float math on the
    activation path."""
    acts = {"input": x_int.astype(jnp.int32)}
    for m in spec["modules"]:
        name = m["name"]
        if m["kind"] == "conv":
            res = acts[m["res"]] if m.get("res") else None
            acts[name] = qconv.qconv2d_pallas(
                acts[m["src"]], weights[f"{name}/w"], weights[f"{name}/b"],
                shifts[name], stride=m["stride"], n_bits=n_bits,
                relu=m["relu"], res_int=res)
        elif m["kind"] == "gap":
            acts[name] = ref.global_avg_pool_int(acts[m["src"]], n_bits,
                                                 unsigned=False)
        elif m["kind"] == "dense":
            acts[name] = qconv.qgemm_pallas(
                acts[m["src"]], weights[f"{name}/w"], weights[f"{name}/b"],
                shifts[name], n_bits=n_bits, relu=m["relu"])
    return acts[spec["modules"][-1]["name"]]


def q_forward_flat(spec: dict, n_bits: int = 8):
    """Return (fn, input_names): a flat-argument version of q_forward for
    AOT lowering — PJRT executables take positional buffers, so the rust
    runtime needs a stable argument order: [x_int, then per module
    (w, b, shifts)...] (see manifest)."""
    mods = q_modules(spec)

    def fn(x_int, *flat):
        weights, shifts = {}, {}
        it = iter(flat)
        for m in mods:
            weights[f"{m['name']}/w"] = next(it)
            weights[f"{m['name']}/b"] = next(it)
            shifts[m["name"]] = next(it)
        return (q_forward(spec, x_int, weights, shifts, n_bits),)

    names = ["x_int"]
    for m in mods:
        names += [f"{m['name']}/w", f"{m['name']}/b", f"{m['name']}/shifts"]
    return fn, names
