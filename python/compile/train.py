"""Build-time training of the models that stand in for the paper's
pretrained networks (TF-slim ResNet-50/101/152, Faster R-CNN/ResNet-152).

Runs once under ``make artifacts``:

  1. generate the seeded synthetic datasets (data.py),
  2. train ResNet-S/M/L on SynthImageNet and DetNet on SynthKITTI with
     SGD + momentum + cosine LR (hand-rolled; no optax in this image),
  3. write datasets + raw (unfolded) weights + a training report to
     ``artifacts/``.

Everything is deterministic (fixed seeds) so artifacts are reproducible.
Python never runs at inference time — the rust binary consumes the
exported ``.dfqt``/HLO files only.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import data as dat
from . import dfqt, model

SEED = 7
CLS_TRAIN, CLS_VAL = 8000, 2000
DET_TRAIN, DET_VAL = 2000, 500


# --------------------------------------------------------------------------
# SGD + momentum + cosine schedule
# --------------------------------------------------------------------------

def sgd_init(params):
    return {k: jnp.zeros_like(v) for k, v in params.items()}


def sgd_step(params, grads, mom, lr, momentum=0.9, wd=1e-4):
    new_p, new_m = {}, {}
    for k in params:
        g = grads[k] + wd * params[k]
        m = momentum * mom[k] + g
        new_m[k] = m
        new_p[k] = params[k] - lr * m
    return new_p, new_m


def cosine_lr(step, total, base=0.08, warmup=50):
    warm = base * (step + 1) / warmup
    t = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = base * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup, warm, cos)


# --------------------------------------------------------------------------
# Classification
# --------------------------------------------------------------------------

def _ce_loss(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def train_classifier(spec: dict, images: np.ndarray, labels: np.ndarray,
                     epochs: int, batch: int, seed: int, log):
    params = {k: jnp.asarray(v) for k, v in
              model.init_params(spec, seed).items()}
    train_p, bn_state = model.split_trainable(params)
    mom = sgd_init(train_p)
    n = images.shape[0]
    steps_per_epoch = n // batch
    total = steps_per_epoch * epochs

    def loss_fn(tp, state, x, y):
        out, new_state, _ = model.fp_forward(spec, {**tp, **state}, x,
                                             train=True)
        return _ce_loss(out, y), new_state

    @jax.jit
    def step_fn(tp, state, mom, x, y, lr):
        (loss, new_state), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(tp, state, x, y)
        tp, mom = sgd_step(tp, grads, mom, lr)
        state = {**state, **new_state}
        return tp, state, mom, loss

    rng = np.random.default_rng(seed + 1)
    step = 0
    for ep in range(epochs):
        order = rng.permutation(n)
        ep_loss = 0.0
        for i in range(steps_per_epoch):
            idx = order[i * batch:(i + 1) * batch]
            x = jnp.asarray(dat.normalize(images[idx]))
            y = jnp.asarray(labels[idx])
            lr = cosine_lr(step, total)
            train_p, bn_state, mom, loss = step_fn(train_p, bn_state, mom,
                                                   x, y, lr)
            ep_loss += float(loss)
            step += 1
        log(f"  epoch {ep + 1}/{epochs} loss={ep_loss / steps_per_epoch:.4f}")
    return {**{k: np.asarray(v) for k, v in train_p.items()},
            **{k: np.asarray(v) for k, v in bn_state.items()}}


def eval_classifier(spec, params, images, labels, batch=200):
    params_j = {k: jnp.asarray(v) for k, v in params.items()}

    @jax.jit
    def fwd(x):
        out, _, _ = model.fp_forward(spec, params_j, x, train=False)
        return jnp.argmax(out, axis=1)

    correct = 0
    for i in range(0, images.shape[0], batch):
        x = jnp.asarray(dat.normalize(images[i:i + batch]))
        correct += int(jnp.sum(fwd(x) == jnp.asarray(labels[i:i + batch])))
    return correct / images.shape[0]


# --------------------------------------------------------------------------
# Detection
# --------------------------------------------------------------------------

def det_targets(labels: np.ndarray, gh: int, gw: int, n_classes: int):
    """labels (N, MAX, 6) -> per-cell targets:
    obj (N,gh,gw), cls (N,gh,gw) int, box (N,gh,gw,4) in [0,1]."""
    n = labels.shape[0]
    obj = np.zeros((n, gh, gw), np.float32)
    cls = np.zeros((n, gh, gw), np.int32)
    box = np.zeros((n, gh, gw, 4), np.float32)
    for i in range(n):
        for row in labels[i]:
            if row[0] < 0.5:
                continue
            c, cx, cy, w, h = int(row[1]), row[2], row[3], row[4], row[5]
            ix = min(gw - 1, int(cx * gw))
            iy = min(gh - 1, int(cy * gh))
            obj[i, iy, ix] = 1.0
            cls[i, iy, ix] = c
            box[i, iy, ix] = (cx * gw - ix, cy * gh - iy, w, h)
    return obj, cls, box


def det_loss(pred, obj_t, cls_t, box_t, n_classes: int):
    """pred (N,gh,gw,1+C+4). BCE objectness over all cells; CE + L2 box on
    positive cells."""
    obj_logit = pred[..., 0]
    cls_logit = pred[..., 1:1 + n_classes]
    box_pred = jax.nn.sigmoid(pred[..., 1 + n_classes:])
    obj_p = jax.nn.sigmoid(obj_logit)
    eps = 1e-6
    bce = -(obj_t * jnp.log(obj_p + eps)
            + (1 - obj_t) * jnp.log(1 - obj_p + eps))
    # class imbalance: ~3% positive cells
    bce = jnp.where(obj_t > 0.5, 4.0 * bce, bce)
    logp = jax.nn.log_softmax(cls_logit)
    onehot = jax.nn.one_hot(cls_t, n_classes)
    ce = -jnp.sum(onehot * logp, axis=-1)
    l2 = jnp.sum((box_pred - box_t) ** 2, axis=-1)
    pos = obj_t
    npos = jnp.maximum(jnp.sum(pos), 1.0)
    return (jnp.mean(bce) + jnp.sum(pos * ce) / npos
            + 2.0 * jnp.sum(pos * l2) / npos)


def train_detector(spec, images, labels, epochs, batch, seed, log):
    gh, gw = spec["grid"]["h"], spec["grid"]["w"]
    ncls = spec["n_classes"]
    obj_t, cls_t, box_t = det_targets(labels, gh, gw, ncls)
    params = {k: jnp.asarray(v) for k, v in
              model.init_params(spec, seed).items()}
    train_p, bn_state = model.split_trainable(params)
    mom = sgd_init(train_p)
    n = images.shape[0]
    steps_per_epoch = n // batch
    total = steps_per_epoch * epochs

    def loss_fn(tp, state, x, ot, ct, bt):
        out, new_state, _ = model.fp_forward(spec, {**tp, **state}, x,
                                             train=True)
        return det_loss(out, ot, ct, bt, ncls), new_state

    @jax.jit
    def step_fn(tp, state, mom, x, ot, ct, bt, lr):
        (loss, new_state), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(tp, state, x, ot, ct, bt)
        tp, mom = sgd_step(tp, grads, mom, lr, wd=5e-5)
        return tp, {**state, **new_state}, mom, loss

    rng = np.random.default_rng(seed + 2)
    step = 0
    for ep in range(epochs):
        order = rng.permutation(n)
        ep_loss = 0.0
        for i in range(steps_per_epoch):
            idx = order[i * batch:(i + 1) * batch]
            x = jnp.asarray(dat.normalize(images[idx]))
            lr = cosine_lr(step, total, base=0.04)
            train_p, bn_state, mom, loss = step_fn(
                train_p, bn_state, mom, x, jnp.asarray(obj_t[idx]),
                jnp.asarray(cls_t[idx]), jnp.asarray(box_t[idx]), lr)
            ep_loss += float(loss)
            step += 1
        log(f"  epoch {ep + 1}/{epochs} loss={ep_loss / steps_per_epoch:.4f}")
    return {**{k: np.asarray(v) for k, v in train_p.items()},
            **{k: np.asarray(v) for k, v in bn_state.items()}}


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--epochs", type=int, default=14)
    ap.add_argument("--det-epochs", type=int, default=20)
    ap.add_argument("--quick", action="store_true",
                    help="tiny run for CI smoke tests")
    args = ap.parse_args()

    out = args.out
    os.makedirs(f"{out}/weights", exist_ok=True)
    os.makedirs(f"{out}/data", exist_ok=True)
    report: Dict = {"models": {}}

    def log(msg):
        print(msg, flush=True)

    cls_train_n = 512 if args.quick else CLS_TRAIN
    cls_val_n = 256 if args.quick else CLS_VAL
    det_train_n = 128 if args.quick else DET_TRAIN
    det_val_n = 64 if args.quick else DET_VAL
    epochs = 2 if args.quick else args.epochs
    det_epochs = 2 if args.quick else args.det_epochs

    log("generating SynthImageNet ...")
    tr_x, tr_y = dat.gen_classification(cls_train_n, seed=SEED)
    va_x, va_y = dat.gen_classification(cls_val_n, seed=SEED + 100)
    dfqt.write_dfqt(f"{out}/data/synthimagenet_train.dfqt",
                    {"images": tr_x, "labels": tr_y})
    dfqt.write_dfqt(f"{out}/data/synthimagenet_val.dfqt",
                    {"images": va_x, "labels": va_y})

    for name in ("resnet_s", "resnet_m", "resnet_l"):
        spec = model.model_spec(name)
        log(f"training {name} ({model.conv_layer_count(spec)} weight layers,"
            f" {epochs} epochs) ...")
        t0 = time.time()
        params = train_classifier(spec, tr_x, tr_y, epochs=epochs,
                                  batch=128, seed=SEED, log=log)
        acc = eval_classifier(spec, params, va_x, va_y)
        log(f"  {name}: val top-1 = {acc * 100:.2f}%"
            f" ({time.time() - t0:.0f}s)")
        dfqt.write_dfqt(f"{out}/weights/{name}.dfqt", params)
        report["models"][name] = {"val_top1": acc,
                                  "train_secs": time.time() - t0}

    log("generating SynthKITTI ...")
    dtr_x, dtr_y = dat.gen_detection(det_train_n, seed=SEED + 500)
    dva_x, dva_y = dat.gen_detection(det_val_n, seed=SEED + 600)
    dfqt.write_dfqt(f"{out}/data/synthkitti_train.dfqt",
                    {"images": dtr_x, "labels": dtr_y})
    dfqt.write_dfqt(f"{out}/data/synthkitti_val.dfqt",
                    {"images": dva_x, "labels": dva_y})

    spec = model.detnet_spec()
    log(f"training detnet ({det_epochs} epochs) ...")
    t0 = time.time()
    params = train_detector(spec, dtr_x, dtr_y, epochs=det_epochs, batch=32,
                            seed=SEED, log=log)
    dfqt.write_dfqt(f"{out}/weights/detnet.dfqt", params)
    report["models"]["detnet"] = {"train_secs": time.time() - t0}

    with open(f"{out}/train_report.json", "w") as f:
        json.dump(report, f, indent=2)
    log("training done.")


if __name__ == "__main__":
    main()
