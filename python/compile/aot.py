"""AOT export: lower the L2/L1 graphs to HLO **text** and write the
manifest the rust runtime consumes.

Interchange is HLO text, NOT ``lowered.compile().serialize()`` — the
image's xla_extension 0.5.1 rejects jax>=0.5 protos with 64-bit
instruction ids; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

All artifacts take weights/shifts as *runtime inputs*, so lowering needs
only shapes — one artifact per topology serves every calibration outcome,
and `make artifacts` can lower before/independently of training.

Artifacts per model (resnet_s/m/l, detnet):
  fp_logits  (batch 16) — BN-folded FP forward, logits only: FP eval path.
  fp_acts    (batch 1)  — folded FP forward returning every unified
                          module's activation: the Eq.-5 oracle fetched in
                          one PJRT call by the rust calibrator.
  q_logits   (batch 16) — integer-only forward built from the Pallas
                          kernels: the serve/eval hot path.
Shared:
  quantize_op / requantize_op — the elementwise Pallas operators.
  qmodule_<sig> (batch 1) — each distinct unified-module signature, for
                          per-module cross-checks and --via-pjrt
                          calibration.

Manifest: artifacts/manifest.json {models: {name: {spec, weights,
artifacts}}, qmodules, ops, datasets}.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import qconv, quant

EVAL_BATCH = 16


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_to_file(fn, args, path: str) -> int:
    text = to_hlo_text(jax.jit(fn).lower(*args))
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def _spec_of(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def module_arg_specs(spec: dict, batch: int, quantized: bool):
    """Argument ShapeDtypeStructs for the flat forwards, plus (name, shape,
    dtype) descriptors for the manifest."""
    h, w, c = spec["input"]["h"], spec["input"]["w"], spec["input"]["c"]
    if quantized:
        args = [_spec_of((batch, h, w, c), jnp.int32)]
        descs = [("x_int", (batch, h, w, c), "i32")]
    else:
        args = [_spec_of((batch, h, w, c), jnp.float32)]
        descs = [("x", (batch, h, w, c), "f32")]
    dt = jnp.int32 if quantized else jnp.float32
    ds = "i32" if quantized else "f32"
    for m in model.q_modules(spec):
        if m["kind"] == "conv":
            wshape = (m["kh"], m["kw"], m["cin"], m["cout"])
        else:
            wshape = (m["cin"], m["cout"])
        bshape = (m["cout"],)
        args += [_spec_of(wshape, dt), _spec_of(bshape, dt)]
        descs += [(f"{m['name']}/w", wshape, ds),
                  (f"{m['name']}/b", bshape, ds)]
        if quantized:
            args.append(_spec_of((3,), jnp.int32))
            descs.append((f"{m['name']}/shifts", (3,), "i32"))
    return args, descs


def export_model(name: str, out: str, manifest: Dict, log) -> None:
    spec = model.model_spec(name)
    entry = {"spec": spec, "weights": f"weights/{name}.dfqt",
             "artifacts": {}}

    for kind, batch, quantized, with_acts in (
            ("fp_logits", EVAL_BATCH, False, False),
            ("fp_acts", 1, False, True),
            ("q_logits", EVAL_BATCH, True, False)):
        if quantized:
            fn, _ = model.q_forward_flat(spec)
        else:
            fn, _ = model.fp_forward_flat(spec, with_acts=with_acts)
        args, descs = module_arg_specs(spec, batch, quantized)
        path = f"hlo/{name}_{kind}.hlo.txt"
        n = lower_to_file(fn, args, f"{out}/{path}")
        outputs = ([m["name"] for m in model.q_modules(spec)]
                   if with_acts else [spec["modules"][-1]["name"]])
        entry["artifacts"][kind] = {
            "path": path, "batch": batch,
            "inputs": [{"name": nm, "shape": list(sh), "dtype": dt}
                       for nm, sh, dt in descs],
            "outputs": outputs,
        }
        log(f"  {name}/{kind}: {n} chars")
    manifest["models"][name] = entry


def qmodule_signatures(specs: List[dict]) -> List[dict]:
    """Distinct (input shape, kernel, stride, relu, residual) signatures
    across all models. Input spatial dims are inferred by walking the
    graph."""
    sigs: Dict[Tuple, dict] = {}
    for spec in specs:
        h, w = spec["input"]["h"], spec["input"]["w"]
        dims = {"input": (h, w)}
        for m in spec["modules"]:
            if m["kind"] == "conv":
                ih, iw = dims[m["src"]]
                oh, ow = -(-ih // m["stride"]), -(-iw // m["stride"])
                dims[m["name"]] = (oh, ow)
                key = (ih, iw, m["cin"], m["cout"], m["kh"], m["kw"],
                       m["stride"], m["relu"], bool(m.get("res")))
                if key not in sigs:
                    sigs[key] = dict(
                        ih=ih, iw=iw, cin=m["cin"], cout=m["cout"],
                        kh=m["kh"], kw=m["kw"], stride=m["stride"],
                        relu=m["relu"], res=bool(m.get("res")),
                        oh=oh, ow=ow)
            elif m["kind"] == "gap":
                dims[m["name"]] = (1, 1)
    return list(sigs.values())


def export_qmodules(specs: List[dict], out: str, manifest: Dict, log):
    for sig in qmodule_signatures(specs):
        tag = (f"qmodule_{sig['ih']}x{sig['iw']}x{sig['cin']}"
               f"_k{sig['kh']}o{sig['cout']}s{sig['stride']}"
               f"{'r' if sig['relu'] else ''}{'x' if sig['res'] else ''}")

        def fn(x_int, w, b, shifts, res=None, _sig=sig):
            return (qconv.qconv2d_pallas(
                x_int, w, b, shifts, stride=_sig["stride"],
                relu=_sig["relu"], res_int=res),)

        args = [
            _spec_of((1, sig["ih"], sig["iw"], sig["cin"]), jnp.int32),
            _spec_of((sig["kh"], sig["kw"], sig["cin"], sig["cout"]),
                     jnp.int32),
            _spec_of((sig["cout"],), jnp.int32),
            _spec_of((3,), jnp.int32),
        ]
        if sig["res"]:
            args.append(_spec_of((1, sig["oh"], sig["ow"], sig["cout"]),
                                 jnp.int32))
        path = f"hlo/{tag}.hlo.txt"
        lower_to_file(fn, args, f"{out}/{path}")
        manifest["qmodules"].append({**sig, "path": path})
        log(f"  {tag}")


def export_ops(out: str, manifest: Dict, log):
    n = 4096

    def quant_fn(x, nf):
        return (quant.quantize_pallas(x, nf),)

    def requant_fn(v, s):
        return (quant.requantize_pallas(v, s, relu=False),)

    lower_to_file(quant_fn,
                  [_spec_of((n,), jnp.float32), _spec_of((1,), jnp.int32)],
                  f"{out}/hlo/quantize_op.hlo.txt")
    lower_to_file(requant_fn,
                  [_spec_of((n,), jnp.int32), _spec_of((1,), jnp.int32)],
                  f"{out}/hlo/requantize_op.hlo.txt")
    manifest["ops"] = {
        "quantize": {"path": "hlo/quantize_op.hlo.txt", "n": n},
        "requantize": {"path": "hlo/requantize_op.hlo.txt", "n": n},
    }
    log("  quantize_op / requantize_op")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="resnet_s,resnet_m,resnet_l,detnet")
    args = ap.parse_args()
    out = args.out
    os.makedirs(f"{out}/hlo", exist_ok=True)

    def log(msg):
        print(msg, flush=True)

    manifest: Dict = {"models": {}, "qmodules": [], "ops": {},
                      "datasets": {
                          "synthimagenet_train": "data/synthimagenet_train.dfqt",
                          "synthimagenet_val": "data/synthimagenet_val.dfqt",
                          "synthkitti_train": "data/synthkitti_train.dfqt",
                          "synthkitti_val": "data/synthkitti_val.dfqt",
                      },
                      "eval_batch": EVAL_BATCH}
    names = args.models.split(",")
    log("lowering model artifacts ...")
    for name in names:
        export_model(name, out, manifest, log)
    log("lowering qmodule artifacts ...")
    specs = [model.model_spec(n) for n in names]
    export_qmodules(specs, out, manifest, log)
    log("lowering op artifacts ...")
    export_ops(out, manifest, log)
    with open(f"{out}/manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)
    log(f"manifest: {out}/manifest.json")


if __name__ == "__main__":
    main()
