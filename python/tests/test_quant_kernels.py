"""Pallas elementwise kernels vs the pure-jnp oracle (exact equality), plus
direct checks of the oracle semantics themselves.

Hypothesis sweeps shapes, bit-widths, fractional bits and value ranges —
the quantize/requantize operators must agree bit-for-bit with ref.py for
any input, since the rust engine mirrors ref.py and the integration tests
chain these equalities into engine == pallas == PJRT.
"""

import jax.numpy as jnp
import numpy as np
import numpy.testing as npt
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quant, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------- oracle

def test_round_half_up_semantics():
    x = jnp.array([-1.5, -0.5, -0.49, 0.0, 0.49, 0.5, 1.5, 2.5])
    npt.assert_array_equal(np.asarray(ref.round_half_up(x)),
                           [-1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 3.0])


def test_qrange():
    assert ref.qrange(8, False) == (-128, 127)
    assert ref.qrange(8, True) == (0, 255)
    assert ref.qrange(6, False) == (-32, 31)
    assert ref.qrange(2, False) == (-2, 1)


def test_quantize_matches_paper_eq1():
    # r^q = clamp(round(r * 2^N)) * 2^-N
    r = jnp.array([0.3, -0.3, 1.7, 100.0, -100.0])
    q = ref.quantize(r, 5, 8)
    scale = 2.0**5
    expect = np.clip(np.floor(np.asarray(r) * scale + 0.5), -128, 127) / scale
    npt.assert_allclose(np.asarray(q), expect)


def test_negative_fractional_bit_selects_upper_digits():
    # N = -3 with 8-bit width: values quantized in steps of 2^3 = 8
    r = jnp.array([12.0, 20.0, 100.0])
    q = ref.quantize(r, -3, 8)
    # 12/8=1.5 -> 2 -> 16;  20/8=2.5 -> 3 -> 24;  100/8=12.5 -> 13 -> 104
    npt.assert_allclose(np.asarray(q), [16.0, 24.0, 104.0])


def test_shift_round_exact_cases():
    v = jnp.array([0, 1, 7, 8, 9, -1, -7, -8, -9, 12, -12], jnp.int32)
    # s=3: round-half-up of v/8
    got = np.asarray(ref.shift_round(v, 3))
    want = np.floor(np.asarray(v) / 8.0 + 0.5).astype(np.int32)
    npt.assert_array_equal(got, want)
    # s=0 identity, s=-2 left shift
    npt.assert_array_equal(np.asarray(ref.shift_round(v, 0)), np.asarray(v))
    npt.assert_array_equal(np.asarray(ref.shift_round(v, -2)),
                           np.asarray(v) * 4)


@given(st.integers(-(2**27), 2**27), st.integers(0, 20))
def test_shift_round_is_floor_half_up(v, s):
    got = int(ref.shift_round(jnp.array([v], jnp.int32), s)[0])
    want = int(np.floor(v / (2.0**s) + 0.5))
    assert got == want


@given(st.integers(-(2**20), 2**20), st.integers(0, 10))
def test_align_inverts_shift_sign(v, s):
    got = int(ref.align(jnp.array([v], jnp.int32), -s)[0])
    assert got == int(ref.shift_round(jnp.array([v], jnp.int32), s)[0])
    got_l = int(ref.align(jnp.array([v], jnp.int32), s)[0])
    assert got_l == v * (2**s)


def test_relu_requant_equivalence():
    """clamp(shift_round(max(acc,0))) == clamp_unsigned(shift_round(acc)) —
    the fusion argument used by the kernel (DESIGN.md)."""
    rng = np.random.default_rng(3)
    acc = jnp.array(rng.integers(-(2**20), 2**20, 4096), jnp.int32)
    fused = ref.requantize(acc, 9, 8, relu=True)
    relu_first = ref.requantize(jnp.maximum(acc, 0), 9, 8, relu=True)
    npt.assert_array_equal(np.asarray(fused), np.asarray(relu_first))


# ---------------------------------------------------------------- pallas

@given(st.integers(1, 4), st.integers(-6, 10),
       st.sampled_from([4, 6, 7, 8]), st.booleans(),
       st.floats(0.1, 50.0))
def test_quantize_pallas_matches_ref(nblocks, n_frac, n_bits, unsigned, amp):
    n = nblocks * 1024
    rng = np.random.default_rng(n + n_frac + n_bits)
    x = rng.normal(0, amp, n).astype(np.float32)
    got = quant.quantize_pallas(jnp.array(x), jnp.array([n_frac], jnp.int32),
                                n_bits=n_bits, unsigned=unsigned)
    want = ref.quantize_int(jnp.array(x), n_frac, n_bits, unsigned)
    npt.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(1, 4), st.integers(-4, 20),
       st.sampled_from([6, 7, 8]), st.booleans())
def test_requantize_pallas_matches_ref(nblocks, shift, n_bits, relu):
    n = nblocks * 1024
    rng = np.random.default_rng(abs(shift) * 31 + n_bits)
    v = rng.integers(-(2**24), 2**24, n).astype(np.int32)
    got = quant.requantize_pallas(jnp.array(v), jnp.array([shift], jnp.int32),
                                  n_bits=n_bits, relu=relu)
    want = ref.requantize(jnp.array(v), shift, n_bits, relu)
    npt.assert_array_equal(np.asarray(got), np.asarray(want))


def test_quantize_pallas_saturates():
    x = jnp.array([1e9, -1e9] * 512, jnp.float32)
    got = np.asarray(quant.quantize_pallas(x, jnp.array([0], jnp.int32)))
    assert got.max() == 127 and got.min() == -128
