"""Training-substrate tests: target assembly, the hand-rolled optimizer,
the LR schedule and the detection loss (fast — no real training)."""

import jax.numpy as jnp
import numpy as np
import numpy.testing as npt

from compile import data as dat
from compile import model, train


def test_det_targets_place_objects_in_cells():
    labels = np.zeros((1, dat.MAX_OBJECTS, 6), np.float32)
    labels[0, 0] = (1.0, 2.0, 0.51, 0.26, 0.2, 0.1)  # cx=0.51, cy=0.26
    obj, cls, box = train.det_targets(labels, gh=8, gw=16, n_classes=3)
    iy, ix = int(0.26 * 8), int(0.51 * 16)
    assert obj[0, iy, ix] == 1.0
    assert obj.sum() == 1.0
    assert cls[0, iy, ix] == 2
    npt.assert_allclose(box[0, iy, ix], [0.51 * 16 - ix, 0.26 * 8 - iy, 0.2, 0.1],
                        rtol=1e-5)


def test_det_targets_edge_clamp():
    labels = np.zeros((1, dat.MAX_OBJECTS, 6), np.float32)
    labels[0, 0] = (1.0, 0.0, 0.999, 0.999, 0.1, 0.1)
    obj, _, _ = train.det_targets(labels, gh=8, gw=16, n_classes=3)
    assert obj[0, 7, 15] == 1.0  # clamped into the last cell


def test_cosine_lr_warmup_and_decay():
    lr0 = float(train.cosine_lr(0, 1000))
    lr_peak = float(train.cosine_lr(50, 1000))
    lr_end = float(train.cosine_lr(999, 1000))
    assert lr0 < lr_peak
    assert lr_end < 0.01 * lr_peak + 1e-6


def test_sgd_momentum_moves_against_gradient():
    params = {"w": jnp.array([1.0, -1.0])}
    grads = {"w": jnp.array([0.5, -0.5])}
    mom = train.sgd_init(params)
    p1, m1 = train.sgd_step(params, grads, mom, lr=0.1, wd=0.0)
    assert float(p1["w"][0]) < 1.0
    assert float(p1["w"][1]) > -1.0
    # momentum accumulates
    p2, _ = train.sgd_step(p1, grads, m1, lr=0.1, wd=0.0)
    step1 = 1.0 - float(p1["w"][0])
    step2 = float(p1["w"][0]) - float(p2["w"][0])
    assert step2 > step1


def test_det_loss_decreases_with_correct_predictions():
    gh, gw, ncls = 4, 4, 3
    obj = np.zeros((1, gh, gw), np.float32)
    obj[0, 1, 1] = 1.0
    cls = np.zeros((1, gh, gw), np.int32)
    cls[0, 1, 1] = 1
    box = np.zeros((1, gh, gw, 4), np.float32)
    box[0, 1, 1] = (0.5, 0.5, 0.3, 0.2)

    bad = np.zeros((1, gh, gw, 1 + ncls + 4), np.float32)
    good = bad.copy()
    good[0, :, :, 0] = -8.0          # background everywhere...
    good[0, 1, 1, 0] = 8.0           # ...except the object cell
    good[0, 1, 1, 2] = 6.0           # correct class logit
    # box: sigmoid^-1 of targets
    good[0, 1, 1, 4:6] = 0.0         # sigmoid(0) = 0.5 = dx, dy
    good[0, 1, 1, 6] = np.log(0.3 / 0.7)
    good[0, 1, 1, 7] = np.log(0.2 / 0.8)

    l_bad = float(train.det_loss(jnp.array(bad), jnp.array(obj),
                                 jnp.array(cls), jnp.array(box), ncls))
    l_good = float(train.det_loss(jnp.array(good), jnp.array(obj),
                                  jnp.array(cls), jnp.array(box), ncls))
    assert l_good < l_bad / 3.0


def test_split_trainable_separates_bn_stats():
    spec = model.resnet_spec(1)
    params = model.init_params(spec, 0)
    trainable, state = model.split_trainable(params)
    assert all("/bn/mean" not in k and "/bn/var" not in k for k in trainable)
    assert all(k.endswith("/bn/mean") or k.endswith("/bn/var") for k in state)
    assert len(trainable) + len(state) == len(params)
