"""Round-trip tests for the .dfqt tensor interchange format."""

import io
import os
import tempfile

import numpy as np
import pytest

from compile import dfqt


def _roundtrip(tensors):
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.dfqt")
        dfqt.write_dfqt(path, tensors)
        return dfqt.read_dfqt(path)


def test_roundtrip_all_dtypes():
    rng = np.random.default_rng(0)
    tensors = {
        "f32": rng.normal(size=(3, 4, 5)).astype(np.float32),
        "i8": rng.integers(-128, 127, (7,)).astype(np.int8),
        "i32": rng.integers(-(2**30), 2**30, (2, 2)).astype(np.int32),
        "u8": rng.integers(0, 255, (4, 4, 3)).astype(np.uint8),
        "i64": rng.integers(-(2**40), 2**40, (3,)).astype(np.int64),
    }
    out = _roundtrip(tensors)
    assert list(out.keys()) == list(tensors.keys())
    for k in tensors:
        np.testing.assert_array_equal(out[k], tensors[k])
        assert out[k].dtype == tensors[k].dtype


def test_roundtrip_scalar_and_empty():
    out = _roundtrip({"scalar": np.float32(3.5).reshape(()),
                      "empty": np.zeros((0, 4), np.float32)})
    assert out["scalar"].shape == ()
    assert float(out["scalar"]) == 3.5
    assert out["empty"].shape == (0, 4)


def test_order_preserved():
    names = [f"t{i}" for i in range(20)]
    tensors = {n: np.full((2,), i, np.float32) for i, n in enumerate(names)}
    out = _roundtrip(tensors)
    assert list(out.keys()) == names


def test_bad_magic_rejected(tmp_path):
    p = tmp_path / "bad.dfqt"
    p.write_bytes(b"NOTDFQT" + b"\x00" * 16)
    with pytest.raises(ValueError, match="bad magic"):
        dfqt.read_dfqt(str(p))


def test_unsupported_dtype_rejected(tmp_path):
    with pytest.raises(ValueError, match="unsupported dtype"):
        dfqt.write_dfqt(str(tmp_path / "x.dfqt"),
                        {"f64": np.zeros(3, np.float64)})


def test_unicode_names():
    out = _roundtrip({"stage0/блок/γ": np.ones(3, np.float32)})
    assert "stage0/блок/γ" in out
