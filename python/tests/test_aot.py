"""AOT export machinery tests (fast parts: signatures, manifest assembly,
HLO text emission for a tiny module). The full-model lowering is exercised
by `make artifacts` + the rust PJRT integration tests."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_qmodule_signatures_deduplicate_across_depths():
    specs = [model.model_spec(n) for n in ("resnet_s", "resnet_m", "resnet_l")]
    sigs = aot.qmodule_signatures(specs)
    # deeper resnets reuse resnet_s's module shapes except the non-first
    # in-stage blocks (c1 without downsampling at 16x16 and 8x8, and the
    # 8x8 residual+ReLU case that S's final-block Fig.-1d variant lacks):
    # exactly three extra signatures
    sigs_s = aot.qmodule_signatures([model.model_spec("resnet_s")])
    assert len(sigs) == len(sigs_s) + 3
    # all strides/channels consistent with the family
    for s in sigs:
        assert s["stride"] in (1, 2)
        assert s["cin"] in (3, 16, 32, 64)
        assert s["oh"] == -(-s["ih"] // s["stride"])


def test_qmodule_signatures_include_all_fig1_cases():
    sigs = aot.qmodule_signatures([model.model_spec("resnet_s")])
    assert any(s["res"] and s["relu"] for s in sigs)       # (c)
    assert any(s["res"] and not s["relu"] for s in sigs)   # (d)
    assert any(not s["res"] and s["relu"] for s in sigs)   # (b)
    assert any(not s["res"] and not s["relu"] for s in sigs)  # (a)


def test_module_arg_specs_order_matches_contract():
    spec = model.model_spec("detnet")
    args, descs = aot.module_arg_specs(spec, batch=4, quantized=True)
    assert descs[0][0] == "x_int"
    assert descs[1][0] == "bb0/w"
    assert descs[2][0] == "bb0/b"
    assert descs[3][0] == "bb0/shifts"
    assert len(args) == len(descs)
    # quantized graphs carry i32 everywhere
    assert all(d[2] == "i32" for d in descs)
    args_fp, descs_fp = aot.module_arg_specs(spec, batch=4, quantized=False)
    assert len(descs_fp) == 1 + 2 * len(model.q_modules(spec))
    assert all(d[2] == "f32" for d in descs_fp)


def test_lower_tiny_module_emits_hlo_text():
    def fn(x, y):
        return (x @ y,)

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.hlo.txt")
        n = aot.lower_to_file(
            fn,
            [jax.ShapeDtypeStruct((4, 4), jnp.float32)] * 2,
            path,
        )
        text = open(path).read()
        assert n == len(text)
        assert "HloModule" in text
        assert "dot" in text  # the matmul survived lowering


def test_ops_export_runs(tmp_path):
    manifest = {"ops": {}}
    os.makedirs(tmp_path / "hlo", exist_ok=True)
    aot.export_ops(str(tmp_path), manifest, lambda m: None)
    assert (tmp_path / "hlo/quantize_op.hlo.txt").exists()
    assert (tmp_path / "hlo/requantize_op.hlo.txt").exists()
    assert manifest["ops"]["quantize"]["n"] == 4096
    # emitted HLO is parseable text with an entry computation
    text = (tmp_path / "hlo/quantize_op.hlo.txt").read_text()
    assert text.startswith("HloModule")


def test_manifest_spec_json_serialisable():
    spec = model.model_spec("resnet_m")
    text = json.dumps(spec)
    back = json.loads(text)
    assert back["modules"][0]["name"] == "stem"
    assert back["input"] == {"h": 32, "w": 32, "c": 3}
