"""The fused unified-module Pallas kernel vs the conv oracle, across the
Fig. 1 cases, strides, shapes and bit-widths (exact integer equality)."""

import jax.numpy as jnp
import numpy as np
import numpy.testing as npt
from hypothesis import given, settings, strategies as st

from compile.kernels import qconv, ref

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def _rand_module(rng, h, w, c, o, kh, kw, unsigned_in):
    lo, hi = (0, 255) if unsigned_in else (-128, 127)
    x = rng.integers(lo, hi, (2, h, w, c)).astype(np.int32)
    wgt = rng.integers(-128, 127, (kh, kw, c, o)).astype(np.int32)
    b = rng.integers(-128, 127, o).astype(np.int32)
    return x, wgt, b


CASES = st.tuples(
    st.sampled_from([(8, 8), (9, 7), (16, 16), (5, 5)]),  # H, W
    st.sampled_from([1, 3, 4]),                           # C
    st.sampled_from([1, 5, 8]),                           # O
    st.sampled_from([(1, 1), (3, 3)]),                    # kernel
    st.sampled_from([1, 2]),                              # stride
    st.booleans(),                                        # relu
)


@given(CASES, st.integers(0, 6), st.integers(4, 12))
def test_qconv_matches_oracle(case, bias_shift, out_shift):
    (h, w), c, o, (kh, kw), stride, relu = case
    rng = np.random.default_rng(h * 31 + c * 7 + o + kh + stride)
    x, wgt, b = _rand_module(rng, h, w, c, o, kh, kw, unsigned_in=True)
    sh = np.array([bias_shift, out_shift, 0], np.int32)
    got = qconv.qconv2d_pallas(jnp.array(x), jnp.array(wgt), jnp.array(b),
                               jnp.array(sh), stride=stride, relu=relu)
    want = ref.qmodule_ref(jnp.array(x), jnp.array(wgt), jnp.array(b),
                           bias_shift, out_shift, stride=stride, relu=relu)
    npt.assert_array_equal(np.asarray(got), np.asarray(want))


@given(CASES, st.integers(-2, 8))
def test_qconv_residual_case(case, res_shift):
    """Fig. 1 (c)/(d): residual aligned into the accumulator domain."""
    (h, w), c, o, (kh, kw), stride, relu = case
    rng = np.random.default_rng(h + c * 13 + o * 3 + res_shift)
    x, wgt, b = _rand_module(rng, h, w, c, o, kh, kw, unsigned_in=True)
    oh, ow = -(-h // stride), -(-w // stride)
    r = rng.integers(0, 255, (2, oh, ow, o)).astype(np.int32)
    sh = np.array([2, 9, res_shift], np.int32)
    got = qconv.qconv2d_pallas(jnp.array(x), jnp.array(wgt), jnp.array(b),
                               jnp.array(sh), stride=stride, relu=relu,
                               res_int=jnp.array(r))
    want = ref.qmodule_ref(jnp.array(x), jnp.array(wgt), jnp.array(b),
                           2, 9, stride=stride, relu=relu,
                           res_int=jnp.array(r), res_shift=res_shift)
    npt.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qgemm_dense_path():
    """Dense layers ride the same kernel as (M,K)x(K,N)."""
    rng = np.random.default_rng(11)
    p = rng.integers(-128, 127, (16, 64)).astype(np.int32)
    w = rng.integers(-128, 127, (64, 10)).astype(np.int32)
    b = rng.integers(-128, 127, 10).astype(np.int32)
    sh = np.array([1, 7, 0], np.int32)
    got = qconv.qgemm_pallas(jnp.array(p), jnp.array(w), jnp.array(b),
                             jnp.array(sh))
    want = ref.qgemm_ref(jnp.array(p), jnp.array(w), jnp.array(b), 1, 7)
    npt.assert_array_equal(np.asarray(got), np.asarray(want))


def test_left_shift_requant_path():
    """out_shift < 0 must left-shift (paper: N_o may exceed N_x + N_w)."""
    rng = np.random.default_rng(5)
    x = rng.integers(0, 3, (1, 4, 4, 2)).astype(np.int32)
    w = rng.integers(-2, 2, (1, 1, 2, 3)).astype(np.int32)
    b = np.zeros(3, np.int32)
    sh = np.array([0, -2, 0], np.int32)
    got = qconv.qconv2d_pallas(jnp.array(x), jnp.array(w), jnp.array(b),
                               jnp.array(sh))
    want = ref.qmodule_ref(jnp.array(x), jnp.array(w), jnp.array(b), 0, -2)
    npt.assert_array_equal(np.asarray(got), np.asarray(want))


def test_im2col_ordering_matches_hwio_flatten():
    """(kh, kw, C)-major patches must match w.reshape(kh*kw*C, O)."""
    rng = np.random.default_rng(2)
    x = jnp.array(rng.normal(size=(1, 6, 6, 3)).astype(np.float32))
    w = jnp.array(rng.normal(size=(3, 3, 3, 4)).astype(np.float32))
    patches, (n, ho, wo) = ref.im2col_nhwc(x, 3, 3, 1, "SAME")
    via_gemm = (patches @ w.reshape(-1, 4)).reshape(n, ho, wo, 4)
    import jax
    direct = jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    npt.assert_allclose(np.asarray(via_gemm), np.asarray(direct),
                        rtol=1e-5, atol=1e-5)


def test_accumulator_stays_int32_exact():
    """Max-magnitude codes through the largest model K (3*3*64) must not
    overflow int32: 576 * 128 * 255 = 18.8M << 2^31."""
    x = jnp.full((1, 4, 4, 64), 255, jnp.int32)
    w = jnp.full((3, 3, 64, 4), -128, jnp.int32)
    b = jnp.zeros(4, jnp.int32)
    sh = jnp.array([0, 0, 0], jnp.int32)
    got = qconv.qconv2d_pallas(x, w, b, sh)
    want = ref.qmodule_ref(x, w, b, 0, 0)
    npt.assert_array_equal(np.asarray(got), np.asarray(want))
