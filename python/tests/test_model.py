"""L2 model tests: spec structure, BN folding equivalence, quantized
forward sanity, and the flat-argument AOT wrappers."""

import jax.numpy as jnp
import numpy as np
import numpy.testing as npt
import pytest

from compile import data as dat
from compile import model
from compile.kernels import ref


def test_resnet_spec_has_all_four_fig1_cases():
    spec = model.resnet_spec(1)
    mods = {m["name"]: m for m in spec["modules"]}
    # (a) bare conv: projection shortcut, no relu, no res
    assert not mods["s1b0/proj"]["relu"] and not mods["s1b0/proj"].get("res")
    # (b) conv + relu
    assert mods["stem"]["relu"] and not mods["stem"].get("res")
    # (c) residual + relu
    assert mods["s0b0/c2"]["relu"] and mods["s0b0/c2"]["res"] == "stem"
    # (d) residual without relu (final block)
    assert not mods["s2b0/c2"]["relu"] and mods["s2b0/c2"]["res"]


def test_resnet_depths():
    for name, layers in (("resnet_s", 10), ("resnet_m", 22),
                         ("resnet_l", 34)):
        spec = model.model_spec(name)
        assert model.conv_layer_count(spec) == layers


def test_spec_dataflow_is_topologically_ordered():
    for name in ("resnet_l", "detnet"):
        spec = model.model_spec(name)
        seen = {"input"}
        for m in spec["modules"]:
            assert m["src"] in seen, (name, m)
            if m.get("res"):
                assert m["res"] in seen
            seen.add(m["name"])


def test_final_spatial_is_power_of_two():
    """global_avg_pool_int needs a power-of-two spatial size (exact shift)."""
    spec = model.resnet_spec(2)
    h = spec["input"]["h"]
    strides = [m["stride"] for m in spec["modules"]
               if m["kind"] == "conv" and "proj" not in m["name"]
               and m["stride"] > 1]
    final = h // int(np.prod(strides))
    assert (final * final) & (final * final - 1) == 0


def test_bn_fold_equivalence():
    """Folded conv+bias forward == BN eval forward (paper §1.2.1)."""
    spec = model.resnet_spec(1)
    params = model.init_params(spec, seed=0)
    rng = np.random.default_rng(1)
    # randomise BN stats so folding is non-trivial
    for k in list(params):
        if "/bn/mean" in k:
            params[k] = rng.normal(0, 0.5, params[k].shape).astype(np.float32)
        if "/bn/var" in k:
            params[k] = rng.uniform(0.5, 2.0, params[k].shape).astype(
                np.float32)
        if "/bn/gamma" in k:
            params[k] = rng.uniform(0.5, 1.5, params[k].shape).astype(
                np.float32)
        if "/bn/beta" in k:
            params[k] = rng.normal(0, 0.3, params[k].shape).astype(np.float32)
    x = jnp.array(rng.normal(0, 1, (2, 32, 32, 3)).astype(np.float32))
    out_bn, _, _ = model.fp_forward(spec, {k: jnp.asarray(v) for k, v in
                                           params.items()}, x, train=False)
    folded = model.fold_bn(spec, params)
    out_folded, _ = model.fp_forward_folded(
        spec, x, {k: jnp.asarray(v) for k, v in folded.items()})
    npt.assert_allclose(np.asarray(out_bn), np.asarray(out_folded),
                        rtol=1e-4, atol=1e-4)


def test_q_forward_shapes_and_determinism():
    spec = model.resnet_spec(1)
    rng = np.random.default_rng(2)
    weights, shifts = {}, {}
    for m in model.q_modules(spec):
        if m["kind"] == "conv":
            wshape = (m["kh"], m["kw"], m["cin"], m["cout"])
        else:
            wshape = (m["cin"], m["cout"])
        weights[f"{m['name']}/w"] = jnp.array(
            rng.integers(-128, 127, wshape), jnp.int32)
        weights[f"{m['name']}/b"] = jnp.array(
            rng.integers(-128, 127, (m["cout"],)), jnp.int32)
        shifts[m["name"]] = jnp.array([2, 10, 4], jnp.int32)
    x = jnp.array(rng.integers(-64, 64, (2, 32, 32, 3)), jnp.int32)
    out1 = model.q_forward(spec, x, weights, shifts)
    out2 = model.q_forward(spec, x, weights, shifts)
    assert out1.shape == (2, 10)
    assert out1.dtype == jnp.int32
    npt.assert_array_equal(np.asarray(out1), np.asarray(out2))
    # 8-bit signed output range
    assert np.asarray(out1).max() <= 127 and np.asarray(out1).min() >= -128


def test_flat_wrappers_argument_order():
    spec = model.detnet_spec()
    fn, names = model.q_forward_flat(spec)
    assert names[0] == "x_int"
    assert names[1:4] == ["bb0/w", "bb0/b", "bb0/shifts"]
    assert names[-3:] == ["head/w", "head/b", "head/shifts"]
    fn_fp, names_fp = model.fp_forward_flat(spec, with_acts=True)
    assert len(names_fp) == 1 + 2 * len(model.q_modules(spec))


def test_detnet_grid():
    spec = model.detnet_spec()
    assert spec["grid"] == {"h": 8, "w": 16}
    head = spec["modules"][-1]
    assert head["cout"] == 1 + 3 + 4


def test_normalize_range():
    u8 = np.array([[0, 127, 255]], np.uint8)
    x = dat.normalize(u8)
    npt.assert_allclose(x, [[-2.0, -0.00784314, 2.0]], atol=1e-5)


def test_datasets_deterministic():
    a_img, a_lab = dat.gen_classification(8, seed=42)
    b_img, b_lab = dat.gen_classification(8, seed=42)
    npt.assert_array_equal(a_img, b_img)
    npt.assert_array_equal(a_lab, b_lab)
    di, dl = dat.gen_detection(4, seed=9)
    assert di.shape == (4, 64, 128, 3)
    assert dl.shape == (4, dat.MAX_OBJECTS, 6)
    # every image has at least one object with valid box
    assert (dl[:, 0, 0] == 1).all()
    assert (dl[..., 2:][dl[..., 0] > 0] >= 0).all()
    assert (dl[..., 2:][dl[..., 0] > 0] <= 1).all()


def test_gap_int_is_exact_shift():
    rng = np.random.default_rng(3)
    x = jnp.array(rng.integers(0, 255, (2, 8, 8, 4)), jnp.int32)
    got = ref.global_avg_pool_int(x, 8, unsigned=True)
    want = np.floor(np.asarray(x).sum(axis=(1, 2)) / 64.0 + 0.5)
    npt.assert_array_equal(np.asarray(got), np.clip(want, 0, 255))
